//! `repro` — the launcher CLI for the VGC reproduction.
//!
//! Subcommands map 1:1 onto DESIGN.md's experiment index:
//!
//! ```text
//! repro train --model vgg_tiny --codec vgc:alpha=1.5 [--steps N ...]
//! repro table1 [--optimizers adam,momentum] [--steps N] [--out results.json]
//! repro table2 [...]
//! repro fig3   [--out fig3.csv]          # scatter data from both tables
//! repro costmodel                         # Section-5 (A5) analysis
//! repro fabric-sweep                      # simulated cluster sweep (F1)
//! repro scale-sweep                       # 256→4096-node event-loop bench
//! repro chaos-sweep                       # fault-injection sweep (chaos fabric)
//! repro inspect                           # artifact manifest summary
//! ```

use anyhow::Result;

use vgc::compress::CodecSpec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::experiments::{
    self, AdaptiveSweepOpts, BenchCodecsOpts, BenchPipelineOpts, ChaosSweepOpts,
    FabricSweepOpts, ScaleSweepOpts,
};
use vgc::fabric::{build_topology, FabricConfig, Straggler, TopologyKind};
use vgc::runtime::{Client, Manifest};
use vgc::service::http::{http_request, http_stream};
use vgc::service::{Daemon, DaemonConfig, JobSpec, QueueConfig};
use vgc::util::alloc::CountingAlloc;
use vgc::util::cli::Args;
use vgc::util::json::Json;
use vgc::util::threadpool::ThreadPool;

/// Counting allocator so `repro bench-codecs` can report steady-state
/// allocation counts for the codec wire path (§Perf zero-allocation
/// contract). One relaxed atomic increment per allocation — noise next
/// to the allocation itself.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc::new();

const USAGE: &str = "\
repro — Variance-based Gradient Compression (ICLR'18) reproduction

USAGE:
  repro train     --model <name> [--codec SPEC] [--optimizer sgd|momentum|adam]
                  [--lr SCHED] [--steps N] [--seed S] [--weight-decay W]
                  [--train-size N] [--test-size N] [--signal F]
                  [--eval-every K] [--log-every K] [--verify-sync]
                  [--codec-threads N]   (0 = auto, 1 = serial wire path)
                  [--loss-curve FILE.csv] [--artifacts DIR]
                  [--topology TOPO] [--torus-dims RxC] [--hier-groups G]
                  [--bandwidth-gbps G] [--latency-us L] [--jitter-us J]
                  [--inter-rack-gbps G] [--segment-bytes N]
                  [--link-overrides SRC-DST:GBPS[:LAT[:JIT]],..]
                  [--stragglers NODE:SLOW,..] [--fabric-seed S]
                  [--faults SPEC | --fault-plan FILE.json]
                  [--on-crash renorm|flush-rejoin]
                  [--bucket-bytes N] [--overlap]  (bucketed overlap pipeline)
                  [--adaptive] [--adaptive-target F]  (closed-loop knob control)
  repro table1    [--optimizers adam,momentum] [--steps N] [--out FILE.json]
  repro table2    [--optimizers adam,momentum] [--steps N] [--out FILE.json]
  repro fig3      [--steps N] [--out FILE.csv]
  repro costmodel
  repro fabric-sweep
                  [--topologies ring,star,full,tree:4,torus,hier:2]
                  [--workers 8,16] [--bandwidth-gbps 1,10]
                  [--inter-rack-gbps G1,G2,..]  (hier uplink skew axis)
                  [--segment-bytes N] [--codecs SPEC+SPEC+..]
                  [--n PARAMS] [--latency-us L] [--jitter-us J]
                  [--stragglers NODE:SLOW,..] [--seed S] [--warmup K]
                  [--overlap] [--bucket-bytes N]  (phased-vs-overlapped columns)
                  [--compute-ns F] [--encode-ns F]  (synthetic ns/param costs)
                  [--out FILE.json] [--md FILE.md]
  repro chaos-sweep
                  [--topologies ring,star,hier:2,..] [--workers P]
                  [--scenarios SPEC+SPEC+..]  (fault specs; 'none' = control)
                  [--codecs SPEC+SPEC+..] [--n PARAMS] [--steps K]
                  [--bandwidth-gbps G] [--latency-us L] [--seed S]
                  [--out FILE.json] [--md FILE.md]
  repro adaptive-sweep
                  [--topologies ring,hier:2,..] [--workers P]
                  [--codecs SPEC+SPEC+..]  (tunable: vgc, strom, adaptive)
                  [--inter-rack-gbps G1,G2,..]  (hier uplink skew axis)
                  [--n PARAMS] [--steps K] [--bandwidth-gbps G]
                  [--latency-us L] [--bucket-bytes N] [--target F]
                  [--compute-ns F] [--encode-ns F] [--seed S]
                  [--out FILE.json] [--md FILE.md]
  repro scale-sweep
                  [--topologies ring,torus,torus3,hier,dragonfly,..]
                  [--workers 256,1024,4096] [--message-bytes N]
                  [--bandwidth-gbps G] [--latency-us L]
                  [--inter-rack-gbps G]  (hier/dragonfly uplink)
                  [--seed S] [--assert-events-per-sec F]
                  [--assert-wall-ms-max F] [--out FILE.json] [--md FILE.md]
  repro bench-codecs
                  [--n PARAMS] [--group SIZE] [--workers P]
                  [--threads T1,T2,..] [--codecs SPEC+SPEC+..]
                  [--alloc-steps K] [--json FILE.json]
  repro bench-pipeline
                  [--topologies ring,torus,hier:2,..] [--workers P]
                  [--bandwidth-gbps G] [--codecs SPEC+SPEC+..]
                  [--n PARAMS] [--bucket-bytes N] [--segment-bytes N]
                  [--compute-ns F] [--encode-ns F] [--seed S]
                  [--json FILE.json] [--md FILE.md]
  repro inspect   [--artifacts DIR]
  repro serve     --listen ADDR:PORT  (0 picks an ephemeral port)
                  [--queues name=limit,..] [--sched-threads N]
                  [--codec-threads N] [--artifacts DIR] [--state FILE.json]
                  [--retry-base-ms M] [--retry-factor F] [--retry-max-ms M]
                  [--topology TOPO] [... fabric flags as for train]
  repro submit    --addr HOST:PORT (--spec FILE.json | --json '{..}')
                  [--watch]    (stream NDJSON events until terminal)
  repro status    --addr HOST:PORT [--job ID]
  repro result    --addr HOST:PORT --job ID [--out FILE.json]
  repro cancel    --addr HOST:PORT --job ID
  repro shutdown  --addr HOST:PORT

Codec SPECs: none | vgc:alpha=A[,zeta=Z] | strom:tau=T |
             hybrid:tau=T,alpha=A | qsgd:bits=B,d=D | terngrad
             (fabric-sweep separates codec specs with '+')
LR SCHEDs:   const:LR | step:LR,FACTOR,EVERY | warmup:LR,STEPS
Topologies:  ring | full | star | tree[:branch] | torus[:RxC] |
             torus3[:XxYxZ] | hier[:groups] | dragonfly[:groups]
             (see docs/TOPOLOGIES.md for cost formulas and guidance,
              docs/SCALE.md for 4096-node sweeps)
Fault SPECs: crash:N@S[+D] | flap:A-B@T1..T2 | drop:A-B:R | corrupt:A-B:R
             (comma-separated; see docs/FAULTS.md for semantics)
";

const TRAIN_FLAGS: &[&str] = &[
    "model", "codec", "optimizer", "lr", "steps", "seed", "weight-decay",
    "train-size", "test-size", "signal", "eval-every", "log-every",
    "verify-sync", "codec-threads", "loss-curve", "artifacts", "on-crash",
    "bucket-bytes", "overlap", "adaptive", "adaptive-target",
];

/// Train accepts its own flags plus the fabric overrides — built at
/// runtime from `FabricConfig::FLAGS` so the lists cannot drift.
fn train_flags() -> Vec<&'static str> {
    let mut flags = TRAIN_FLAGS.to_vec();
    flags.extend_from_slice(FabricConfig::FLAGS);
    flags
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn main() -> Result<()> {
    let args = Args::from_env(&["verify-sync", "quiet", "watch", "overlap", "adaptive"])?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "train" => cmd_train(&args),
        "table1" => cmd_table(&args, "table1"),
        "table2" => cmd_table(&args, "table2"),
        "fig3" => cmd_fig3(&args),
        "costmodel" => {
            print!("{}", experiments::costmodel_report());
            Ok(())
        }
        "fabric-sweep" => cmd_fabric_sweep(&args),
        "scale-sweep" => cmd_scale_sweep(&args),
        "chaos-sweep" => cmd_chaos_sweep(&args),
        "adaptive-sweep" => cmd_adaptive_sweep(&args),
        "bench-codecs" => cmd_bench_codecs(&args),
        "bench-pipeline" => cmd_bench_pipeline(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "status" => cmd_status(&args),
        "result" => cmd_result(&args),
        "cancel" => cmd_cancel(&args),
        "shutdown" => cmd_shutdown(&args),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    args.check_known(&train_flags())?;
    let model = args.require("model")?;
    let cfg = TrainConfig::defaults(model).override_from(args)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let client = Client::cpu()?;
    println!(
        "model={model} codec={} optimizer={} steps={} codec-threads={} (platform: {})",
        cfg.codec.label(),
        cfg.optimizer,
        cfg.steps,
        cfg.resolved_codec_threads(),
        client.platform()
    );
    let mut trainer = Trainer::new(&client, &manifest, cfg)?;
    {
        // Fail before the run, not after it, if the fabric config names
        // a node this model's cluster does not have.
        let nodes = build_topology(trainer.cfg.fabric.topology, trainer.workers()).node_count();
        for s in &trainer.cfg.fabric.stragglers {
            anyhow::ensure!(
                s.node < nodes,
                "--stragglers names node {} but the {} fabric has {} nodes",
                s.node,
                trainer.cfg.fabric.topology.label(),
                nodes
            );
        }
    }
    let t0 = std::time::Instant::now();
    trainer.run(false)?;
    let wall = t0.elapsed().as_secs_f64();

    let m = &trainer.metrics;
    println!("\n--- run summary ---");
    println!("final loss         {:.4}", m.final_loss());
    if !m.final_accuracy().is_nan() {
        println!("final accuracy     {:.2}%", m.final_accuracy() * 100.0);
    }
    println!("compression ratio  {:.1}", m.compression_ratio());
    println!("bits ratio         {:.1}", m.bits_ratio());
    println!("residual L1        {:.3e}", trainer.residual_l1());
    let ph = trainer.phases;
    println!(
        "wall {wall:.1}s  (compute {:.1}s, encode {:.1}s, comm+decode {:.1}s, update {:.1}s)",
        ph.compute_s, ph.encode_s, ph.comm_decode_s, ph.update_s
    );
    // The comm phase ran every step's allgatherv on the configured
    // fabric topology; report the simulated step-communication time it
    // accumulated on that cluster shape.
    let steps = trainer.step_count();
    if steps > 0 {
        let total_ms = trainer.sim_comm_ps as f64 * 1e-9;
        println!(
            "fabric sim         {}: step comm {:.3} ms/step ({:.3} ms over {steps} steps)",
            trainer.cfg.fabric.describe(),
            total_ms / steps as f64,
            total_ms,
        );
        if trainer.cfg.overlap || trainer.cfg.bucket_bytes > 0 {
            let phased_ms = trainer.sim_phased_ps as f64 * 1e-9;
            let overlap_ms = trainer.sim_overlap_ps as f64 * 1e-9;
            println!(
                "pipeline           phased {:.3} ms, overlapped {:.3} ms ({:.2}x, bucket {} B)",
                phased_ms,
                overlap_ms,
                if overlap_ms > 0.0 {
                    phased_ms / overlap_ms
                } else {
                    1.0
                },
                trainer.cfg.bucket_bytes,
            );
        }
    }
    if let Some(path) = args.get("loss-curve") {
        std::fs::write(path, m.loss_curve_csv())?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn cmd_fabric_sweep(args: &Args) -> Result<()> {
    args.check_known(&[
        "topologies", "workers", "bandwidth-gbps", "inter-rack-gbps", "segment-bytes",
        "codecs", "n", "latency-us", "jitter-us", "stragglers", "seed", "warmup",
        "overlap", "bucket-bytes", "compute-ns", "encode-ns", "out", "md",
    ])?;
    let mut opts = FabricSweepOpts::default();
    let topologies = args
        .list("topologies")
        .iter()
        .map(|t| TopologyKind::parse(t))
        .collect::<Result<Vec<_>>>()?;
    if !topologies.is_empty() {
        opts.topologies = topologies;
    }
    let workers = args.parse_list::<usize>("workers")?;
    if !workers.is_empty() {
        opts.workers = workers;
    }
    let bandwidths = args.parse_list::<f64>("bandwidth-gbps")?;
    if !bandwidths.is_empty() {
        opts.bandwidths_gbps = bandwidths;
    }
    let uplinks = args.parse_list::<f64>("inter-rack-gbps")?;
    if !uplinks.is_empty() {
        opts.inter_rack_gbps = uplinks;
    }
    opts.segment_bytes = args.parse_or("segment-bytes", opts.segment_bytes)?;
    // Codec specs contain commas (vgc:alpha=1.5,zeta=0.999), so the
    // list separator here is '+'.
    if let Some(spec) = args.get("codecs") {
        opts.codecs = spec
            .split('+')
            .filter(|s| !s.trim().is_empty())
            .map(|s| CodecSpec::parse(s.trim()))
            .collect::<Result<Vec<_>>>()?;
    }
    opts.n_params = args.parse_or("n", opts.n_params)?;
    opts.latency_us = args.parse_or("latency-us", opts.latency_us)?;
    opts.jitter_us = args.parse_or("jitter-us", opts.jitter_us)?;
    if let Some(spec) = args.get("stragglers") {
        opts.stragglers = Straggler::parse_list(spec)?;
    }
    opts.seed = args.parse_or("seed", opts.seed)?;
    opts.warmup_steps = args.parse_or("warmup", opts.warmup_steps)?;
    if args.has("overlap") {
        opts.overlap = true;
    }
    opts.bucket_bytes = args.parse_or("bucket-bytes", opts.bucket_bytes)?;
    opts.compute_ns_per_param = args.parse_or("compute-ns", opts.compute_ns_per_param)?;
    opts.encode_ns_per_param = args.parse_or("encode-ns", opts.encode_ns_per_param)?;
    // Same validation the service daemon applies to HTTP submissions.
    experiments::validate_sweep(&opts)?;

    let rows = experiments::fabric_sweep(&opts);
    let md = experiments::fabric_sweep_markdown(&opts, &rows);
    print!("{md}");
    if let Some(path) = args.get("md") {
        std::fs::write(path, &md)?;
        println!("\nmarkdown written to {path}");
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, experiments::fabric_sweep_json(&rows).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn cmd_scale_sweep(args: &Args) -> Result<()> {
    args.check_known(&[
        "topologies", "workers", "message-bytes", "bandwidth-gbps", "latency-us",
        "inter-rack-gbps", "seed", "assert-events-per-sec", "assert-wall-ms-max",
        "out", "md",
    ])?;
    let mut opts = ScaleSweepOpts::default();
    let topologies = args
        .list("topologies")
        .iter()
        .map(|t| TopologyKind::parse(t))
        .collect::<Result<Vec<_>>>()?;
    if !topologies.is_empty() {
        opts.topologies = topologies;
    }
    let workers = args.parse_list::<usize>("workers")?;
    if !workers.is_empty() {
        opts.workers = workers;
    }
    opts.message_bytes = args.parse_or("message-bytes", opts.message_bytes)?;
    opts.bandwidth_gbps = args.parse_or("bandwidth-gbps", opts.bandwidth_gbps)?;
    opts.latency_us = args.parse_or("latency-us", opts.latency_us)?;
    if args.has("inter-rack-gbps") {
        opts.inter_rack_gbps = Some(args.parse_or("inter-rack-gbps", 1.0f64)?);
    }
    opts.seed = args.parse_or("seed", opts.seed)?;
    experiments::validate_scale(&opts)?;

    let rows = experiments::scale_sweep(&opts);
    let md = experiments::scale_sweep_markdown(&opts, &rows);
    print!("{md}");
    if let Some(path) = args.get("md") {
        std::fs::write(path, &md)?;
        println!("\nmarkdown written to {path}");
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, experiments::scale_sweep_json(&opts, &rows).to_string())?;
        println!("\nresults written to {path}");
    }
    // CI gate: fail loudly after the report is printed/written so the
    // offending numbers are always visible in the log.
    let floor = match args.get("assert-events-per-sec") {
        Some(_) => Some(args.parse_or("assert-events-per-sec", 0.0f64)?),
        None => None,
    };
    let ceiling = match args.get("assert-wall-ms-max") {
        Some(_) => Some(args.parse_or("assert-wall-ms-max", 0.0f64)?),
        None => None,
    };
    experiments::enforce_scale(&rows, floor, ceiling)?;
    Ok(())
}

fn cmd_chaos_sweep(args: &Args) -> Result<()> {
    args.check_known(&[
        "topologies", "workers", "scenarios", "codecs", "n", "steps",
        "bandwidth-gbps", "latency-us", "seed", "out", "md",
    ])?;
    let mut opts = ChaosSweepOpts::default();
    let topologies = args
        .list("topologies")
        .iter()
        .map(|t| TopologyKind::parse(t))
        .collect::<Result<Vec<_>>>()?;
    if !topologies.is_empty() {
        opts.topologies = topologies;
    }
    opts.workers = args.parse_or("workers", opts.workers)?;
    // Fault specs contain commas (crash:1@2,drop:0-1:0.3), so the
    // scenario list separator is '+', matching the codec convention.
    if let Some(spec) = args.get("scenarios") {
        opts.scenarios = spec
            .split('+')
            .map(|c| c.trim().to_string())
            .filter(|c| !c.is_empty())
            .collect();
    }
    if let Some(spec) = args.get("codecs") {
        opts.codecs = spec
            .split('+')
            .filter(|c| !c.trim().is_empty())
            .map(|c| CodecSpec::parse(c.trim()))
            .collect::<Result<Vec<_>>>()?;
    }
    opts.n_params = args.parse_or("n", opts.n_params)?;
    opts.steps = args.parse_or("steps", opts.steps)?;
    opts.bandwidth_gbps = args.parse_or("bandwidth-gbps", opts.bandwidth_gbps)?;
    opts.latency_us = args.parse_or("latency-us", opts.latency_us)?;
    opts.seed = args.parse_or("seed", opts.seed)?;

    let rows = experiments::chaos_sweep(&opts)?;
    let md = experiments::chaos_sweep_markdown(&opts, &rows);
    print!("{md}");
    if let Some(path) = args.get("md") {
        std::fs::write(path, &md)?;
        println!("\nmarkdown written to {path}");
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, experiments::chaos_sweep_json(&rows).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn cmd_adaptive_sweep(args: &Args) -> Result<()> {
    args.check_known(&[
        "topologies", "workers", "codecs", "inter-rack-gbps", "n", "steps",
        "bandwidth-gbps", "latency-us", "bucket-bytes", "target", "compute-ns",
        "encode-ns", "seed", "out", "md",
    ])?;
    let mut opts = AdaptiveSweepOpts::default();
    let topologies = args
        .list("topologies")
        .iter()
        .map(|t| TopologyKind::parse(t))
        .collect::<Result<Vec<_>>>()?;
    if !topologies.is_empty() {
        opts.topologies = topologies;
    }
    opts.workers = args.parse_or("workers", opts.workers)?;
    // Codec specs contain commas, so the list separator is '+'.
    if let Some(spec) = args.get("codecs") {
        opts.codecs = spec
            .split('+')
            .filter(|c| !c.trim().is_empty())
            .map(|c| CodecSpec::parse(c.trim()))
            .collect::<Result<Vec<_>>>()?;
    }
    let uplinks = args.parse_list::<f64>("inter-rack-gbps")?;
    if !uplinks.is_empty() {
        opts.inter_rack_gbps = uplinks;
    }
    opts.n_params = args.parse_or("n", opts.n_params)?;
    opts.steps = args.parse_or("steps", opts.steps)?;
    opts.bandwidth_gbps = args.parse_or("bandwidth-gbps", opts.bandwidth_gbps)?;
    opts.latency_us = args.parse_or("latency-us", opts.latency_us)?;
    opts.bucket_bytes = args.parse_or("bucket-bytes", opts.bucket_bytes)?;
    opts.target = args.parse_or("target", opts.target)?;
    opts.compute_ns_per_param = args.parse_or("compute-ns", opts.compute_ns_per_param)?;
    opts.encode_ns_per_param = args.parse_or("encode-ns", opts.encode_ns_per_param)?;
    opts.seed = args.parse_or("seed", opts.seed)?;

    let rows = experiments::adaptive_sweep(&opts)?;
    let md = experiments::adaptive_sweep_markdown(&opts, &rows);
    print!("{md}");
    if let Some(path) = args.get("md") {
        std::fs::write(path, &md)?;
        println!("\nmarkdown written to {path}");
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, experiments::adaptive_sweep_json(&rows).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn cmd_bench_codecs(args: &Args) -> Result<()> {
    args.check_known(&[
        "n", "group", "workers", "threads", "codecs", "alloc-steps", "json",
    ])?;
    let mut opts = BenchCodecsOpts::default();
    let threads = args.parse_list::<usize>("threads")?;
    if !threads.is_empty() {
        anyhow::ensure!(
            threads.iter().all(|&t| t >= 1),
            "--threads values must be >= 1"
        );
        opts.threads = threads;
    }
    opts.n = args.parse_or("n", opts.n)?;
    anyhow::ensure!(opts.n > 0, "--n must be positive");
    opts.group = args.parse_or("group", opts.group)?;
    anyhow::ensure!(opts.group > 0, "--group must be positive");
    opts.workers = args.parse_or("workers", opts.workers)?;
    anyhow::ensure!(opts.workers > 0, "--workers must be positive");
    opts.alloc_steps = args.parse_or("alloc-steps", opts.alloc_steps)?;
    // Codec specs contain commas, so the list separator is '+' (same
    // convention as fabric-sweep).
    if let Some(spec) = args.get("codecs") {
        opts.codecs = spec
            .split('+')
            .filter(|c| !c.trim().is_empty())
            .map(|c| CodecSpec::parse(c.trim()))
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!opts.codecs.is_empty(), "--codecs lists no specs");
    }
    println!(
        "bench-codecs: n={} workers={} threads={:?} (available parallelism: {})",
        opts.n,
        opts.workers,
        opts.threads,
        ThreadPool::available()
    );
    let rows = experiments::bench_codecs(&opts);
    print!("{}", experiments::bench_codecs_markdown(&opts, &rows));
    if let Some(path) = args.get("json") {
        std::fs::write(path, experiments::bench_codecs_json(&opts, &rows).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn cmd_bench_pipeline(args: &Args) -> Result<()> {
    args.check_known(&[
        "topologies", "workers", "bandwidth-gbps", "codecs", "n", "bucket-bytes",
        "segment-bytes", "compute-ns", "encode-ns", "seed", "json", "md",
    ])?;
    let mut opts = BenchPipelineOpts::default();
    let topologies = args
        .list("topologies")
        .iter()
        .map(|t| TopologyKind::parse(t))
        .collect::<Result<Vec<_>>>()?;
    if !topologies.is_empty() {
        opts.topologies = topologies;
    }
    opts.workers = args.parse_or("workers", opts.workers)?;
    opts.bandwidth_gbps = args.parse_or("bandwidth-gbps", opts.bandwidth_gbps)?;
    // Codec specs contain commas, so the list separator is '+'.
    if let Some(spec) = args.get("codecs") {
        opts.codecs = spec
            .split('+')
            .filter(|c| !c.trim().is_empty())
            .map(|c| CodecSpec::parse(c.trim()))
            .collect::<Result<Vec<_>>>()?;
    }
    opts.n_params = args.parse_or("n", opts.n_params)?;
    opts.bucket_bytes = args.parse_or("bucket-bytes", opts.bucket_bytes)?;
    opts.segment_bytes = args.parse_or("segment-bytes", opts.segment_bytes)?;
    opts.compute_ns_per_param = args.parse_or("compute-ns", opts.compute_ns_per_param)?;
    opts.encode_ns_per_param = args.parse_or("encode-ns", opts.encode_ns_per_param)?;
    opts.seed = args.parse_or("seed", opts.seed)?;

    let rows = experiments::bench_pipeline(&opts)?;
    let md = experiments::bench_pipeline_markdown(&opts, &rows);
    print!("{md}");
    if let Some(path) = args.get("md") {
        std::fs::write(path, &md)?;
        println!("\nmarkdown written to {path}");
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, experiments::bench_pipeline_json(&opts, &rows).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn parse_optimizers(args: &Args) -> Vec<String> {
    let list = args.list("optimizers");
    if list.is_empty() {
        vec!["adam".into(), "momentum".into()]
    } else {
        list
    }
}

fn cmd_table(args: &Args, which: &str) -> Result<()> {
    args.check_known(&["optimizers", "steps", "out", "artifacts", "quiet"])?;
    let steps = args.parse_or("steps", 300u64)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let client = Client::cpu()?;
    let mut all = Vec::new();
    for opt in parse_optimizers(args) {
        let rows = match which {
            "table1" => experiments::table1_rows(&opt, steps),
            _ => experiments::table2_rows(&opt, steps),
        };
        let results = experiments::run_grid(&client, &manifest, &rows, args.has("quiet"))?;
        experiments::print_table(
            &format!(
                "{} ({}, {} steps) — paper Table {}",
                if which == "table1" {
                    "CIFAR-10-like / vgg_tiny"
                } else {
                    "ImageNet-like / resnet_mini"
                },
                opt,
                steps,
                if which == "table1" { 1 } else { 2 }
            ),
            &results,
        );
        all.extend(results);
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, experiments::results_json(which, &all).to_string())?;
        println!("\nresults written to {path}");
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    args.check_known(&["steps", "out", "artifacts", "quiet", "from"])?;
    // Preferred path: derive the scatter from saved table results
    // (`--from table1_results.json,table2_results.json`) instead of
    // re-running both grids.
    if args.has("from") {
        let mut csv = String::from("method,optimizer,accuracy,compression,bits_ratio\n");
        let mut count = 0usize;
        for path in args.list("from") {
            let text = std::fs::read_to_string(&path)?;
            let rows = vgc::util::json::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            for r in rows.as_arr()? {
                csv.push_str(&format!(
                    "{}:{},{},{},{},{}\n",
                    r.expect("table")?.as_str()?,
                    r.expect("method")?.as_str()?,
                    r.expect("optimizer")?.as_str()?,
                    r.expect("accuracy")?.as_f64()?,
                    r.expect("compression")?.as_f64()?,
                    r.expect("bits_ratio")?.as_f64()?,
                ));
                count += 1;
            }
        }
        let path = args.str_or("out", "fig3.csv");
        std::fs::write(&path, &csv)?;
        println!("figure-3 scatter data ({count} points) written to {path}");
        return Ok(());
    }
    let steps = args.parse_or("steps", 300u64)?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    let client = Client::cpu()?;
    let mut all = Vec::new();
    for (table, builder) in [
        (
            "table1",
            experiments::table1_rows as fn(&str, u64) -> Vec<experiments::GridRow>,
        ),
        ("table2", experiments::table2_rows),
    ] {
        for opt in ["adam", "momentum"] {
            let rows = builder(opt, steps);
            let mut results =
                experiments::run_grid(&client, &manifest, &rows, args.has("quiet"))?;
            for r in &mut results {
                r.label = format!("{table}:{}", r.label);
            }
            all.extend(results);
        }
    }
    let csv = experiments::fig3_csv(&all);
    let path = args.str_or("out", "fig3.csv");
    std::fs::write(&path, &csv)?;
    println!(
        "figure-3 scatter data ({} points) written to {path}",
        all.len()
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    args.check_known(&["artifacts"])?;
    let manifest = Manifest::load(artifacts_dir(args))?;
    println!("artifact manifest (fingerprint {})", manifest.fingerprint);
    for m in &manifest.models {
        println!(
            "  {:<14} N={:<9} P={:<3} B={:<3} eval_batch={:<4} groups={:<4} kind={}",
            m.name,
            m.n_params,
            m.workers,
            m.batch,
            m.eval_batch,
            m.groups.len(),
            m.kind
        );
    }
    for e in &manifest.moments_bench {
        println!("  [bench] moments b={} n={} ({})", e.b, e.n, e.hlo);
    }
    for e in &manifest.criterion {
        println!("  [bench] criterion n={} ({})", e.n, e.hlo);
    }
    Ok(())
}

/// Serve accepts its own flags plus the fabric overrides (the daemon's
/// shared cluster model), mirroring `train_flags`.
fn serve_flags() -> Vec<&'static str> {
    let mut flags = vec!["listen", "queues", "sched-threads", "codec-threads", "artifacts"];
    flags.extend_from_slice(&["state", "retry-base-ms", "retry-factor", "retry-max-ms"]);
    flags.extend_from_slice(FabricConfig::FLAGS);
    flags
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.check_known(&serve_flags())?;
    let listen = args.str_or("listen", "127.0.0.1:7077");
    let mut cfg = DaemonConfig {
        codec_threads: args.parse_or("codec-threads", 0usize)?,
        artifacts_dir: artifacts_dir(args),
        state_path: args.get("state").map(|p| p.to_string()),
        fabric: FabricConfig::default().override_from(args)?,
        ..DaemonConfig::default()
    };
    if let Some(qspec) = args.get("queues") {
        cfg.scheduler.queues = QueueConfig::parse_list(qspec)?;
    }
    cfg.scheduler.threads = args.parse_or("sched-threads", cfg.scheduler.threads)?;
    cfg.scheduler.retry.base_ms = args.parse_or("retry-base-ms", cfg.scheduler.retry.base_ms)?;
    cfg.scheduler.retry.factor = args.parse_or("retry-factor", cfg.scheduler.retry.factor)?;
    cfg.scheduler.retry.max_ms = args.parse_or("retry-max-ms", cfg.scheduler.retry.max_ms)?;
    let daemon = Daemon::start(cfg);
    daemon.run(&listen)
}

fn cmd_submit(args: &Args) -> Result<()> {
    args.check_known(&["addr", "spec", "json", "watch"])?;
    let addr = args.require("addr")?;
    let body = if let Some(path) = args.get("spec") {
        std::fs::read_to_string(path)?
    } else if let Some(inline) = args.get("json") {
        inline.to_string()
    } else {
        anyhow::bail!("submit needs --spec FILE.json or --json '{{..}}'");
    };
    // Validate client-side so a typo fails fast with a parse error
    // instead of a 400 from the daemon.
    JobSpec::from_json(&Json::parse(&body)?)?;
    let (code, resp) = http_request(addr, "POST", "/jobs", Some(&body))?;
    anyhow::ensure!(code == 200, "submit failed: HTTP {code}: {resp}");
    println!("{resp}");
    if args.has("watch") {
        let id = Json::parse(&resp)?.expect("job")?.as_usize()?;
        http_stream(addr, &format!("/jobs/{id}/events"), &mut |line| {
            println!("{line}");
        })?;
    }
    Ok(())
}

fn cmd_status(args: &Args) -> Result<()> {
    args.check_known(&["addr", "job"])?;
    let addr = args.require("addr")?;
    if let Some(job) = args.get("job") {
        let (code, resp) = http_request(addr, "GET", &format!("/jobs/{job}"), None)?;
        anyhow::ensure!(code == 200, "HTTP {code}: {resp}");
        println!("{resp}");
    } else {
        for path in ["/healthz", "/queues", "/jobs", "/fabric"] {
            let (code, resp) = http_request(addr, "GET", path, None)?;
            anyhow::ensure!(code == 200, "HTTP {code}: {resp}");
            println!("{path} {resp}");
        }
    }
    Ok(())
}

fn cmd_result(args: &Args) -> Result<()> {
    args.check_known(&["addr", "job", "out"])?;
    let addr = args.require("addr")?;
    let job = args.require("job")?;
    let (code, resp) = http_request(addr, "GET", &format!("/jobs/{job}/result"), None)?;
    anyhow::ensure!(code == 200, "HTTP {code}: {resp}");
    if let Some(path) = args.get("out") {
        std::fs::write(path, &resp)?;
        println!("result written to {path}");
    } else {
        println!("{resp}");
    }
    Ok(())
}

fn cmd_cancel(args: &Args) -> Result<()> {
    args.check_known(&["addr", "job"])?;
    let addr = args.require("addr")?;
    let job = args.require("job")?;
    let (code, resp) = http_request(addr, "POST", &format!("/jobs/{job}/cancel"), None)?;
    anyhow::ensure!(code == 200, "HTTP {code}: {resp}");
    println!("{resp}");
    Ok(())
}

fn cmd_shutdown(args: &Args) -> Result<()> {
    args.check_known(&["addr"])?;
    let addr = args.require("addr")?;
    let (code, resp) = http_request(addr, "POST", "/shutdown", None)?;
    anyhow::ensure!(code == 200, "HTTP {code}: {resp}");
    println!("{resp}");
    Ok(())
}
