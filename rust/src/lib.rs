//! `vgc` — Variance-based Gradient Compression for distributed deep
//! learning (Tsuzuku, Imachi & Akiba, ICLR 2018), reproduced as a
//! three-layer Rust + JAX + Pallas system.
//!
//! * L3 (this crate): distributed-training coordinator — compression
//!   codecs, the event-driven cluster fabric simulator (`fabric`) with
//!   pluggable topologies backing the `comm` collectives, optimizers,
//!   data pipeline, metrics, CLI launcher.
//! * L2/L1 (python/, build-time only): JAX model fwd/bwd + the fused
//!   Pallas moment kernel, AOT-lowered to HLO text.
//! * runtime: loads the artifacts via the PJRT C API and executes them
//!   on the request path.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod bench;
pub mod runtime;
pub mod testkit;
pub mod util;

pub mod compress;
pub mod model;
pub mod comm;
pub mod fabric;
pub mod data;
pub mod optim;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod experiments;
pub mod service;
