//! Benchmark harness (the offline crate set has no `criterion`;
//! DESIGN.md §Substitutions).
//!
//! Bench binaries under `rust/benches/` are built with `harness = false`
//! and drive this module: warmup, timed iterations until a target wall
//! budget, then mean / p50 / p95 / throughput reporting in a stable
//! one-line-per-bench format that `EXPERIMENTS.md` quotes directly.

use std::time::{Duration, Instant};

use crate::util::percentile;

pub struct Bencher {
    /// Minimum measured iterations per bench.
    pub min_iters: u32,
    /// Target wall time per bench.
    pub budget: Duration,
    /// Warmup iterations.
    pub warmup: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        // VGC_BENCH_FAST=1 shrinks budgets so `cargo bench` smoke-runs
        // quickly in CI; default budgets give stable medians locally.
        let fast = std::env::var("VGC_BENCH_FAST").is_ok();
        Bencher {
            min_iters: if fast { 3 } else { 10 },
            budget: Duration::from_millis(if fast { 200 } else { 2000 }),
            warmup: if fast { 1 } else { 3 },
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    /// items/sec given `items` work units per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

impl Bencher {
    /// Measure `f`, which performs one full iteration per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters as usize || start.elapsed() < self.budget {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break;
            }
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len() as u32,
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(percentile(&samples, 0.5)),
            p95: Duration::from_secs_f64(percentile(&samples, 0.95)),
        }
    }

    /// Run and print in the standard report format.
    pub fn report<F: FnMut()>(&self, name: &str, f: F) -> BenchResult {
        let r = self.run(name, f);
        println!("{}", format_result(&r, None));
        r
    }

    /// Run and print with a throughput figure (`items` per iteration,
    /// `unit` e.g. "elem", "MB").
    pub fn report_throughput<F: FnMut()>(
        &self,
        name: &str,
        items: f64,
        unit: &str,
        f: F,
    ) -> BenchResult {
        let r = self.run(name, f);
        println!("{}", format_result(&r, Some((items, unit))));
        r
    }
}

fn human_time(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

fn human_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2} G", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2} M", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2} k", r / 1e3)
    } else {
        format!("{r:.2} ")
    }
}

fn format_result(r: &BenchResult, thr: Option<(f64, &str)>) -> String {
    let mut line = format!(
        "bench {:<44} iters={:<5} mean={:<12} p50={:<12} p95={}",
        r.name,
        r.iters,
        human_time(r.mean),
        human_time(r.p50),
        human_time(r.p95),
    );
    if let Some((items, unit)) = thr {
        line.push_str(&format!("  thr={}{}/s", human_rate(r.throughput(items)), unit));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let b = Bencher {
            min_iters: 5,
            budget: Duration::from_millis(10),
            warmup: 1,
        };
        let r = b.run("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.mean.as_secs_f64() >= 0.0);
        assert!(r.p95 >= r.p50);
    }

    #[test]
    fn throughput_is_items_over_mean() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_secs(2),
            p50: Duration::from_secs(2),
            p95: Duration::from_secs(2),
        };
        assert!((r.throughput(10.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn humanizes_times() {
        assert_eq!(human_time(Duration::from_secs(2)), "2.000 s");
        assert_eq!(human_time(Duration::from_millis(5)), "5.000 ms");
        assert!(human_time(Duration::from_nanos(50)).ends_with("ns"));
    }
}
