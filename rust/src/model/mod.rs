//! Model parameter layout: the flat-vector view of a model.
//!
//! The coordinator and every codec see a model as one flat `f32` vector
//! of length N partitioned into named, contiguous *groups* — one per
//! weight tensor. Groups are the paper's quantization scopes (`M_k` is
//! the max |value| within a group, Sec. 4.2). The layout comes from the
//! AOT manifest (`ravel_pytree` order) and is validated on load.

/// One named tensor's span in the flat vector.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamGroup {
    pub name: String,
    pub offset: usize,
    pub len: usize,
}

impl ParamGroup {
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.len
    }
}

/// A validated partition of `[0, n)` into groups.
#[derive(Debug, Clone)]
pub struct Layout {
    n: usize,
    groups: Vec<ParamGroup>,
}

impl Layout {
    pub fn new(n: usize, groups: Vec<ParamGroup>) -> anyhow::Result<Layout> {
        anyhow::ensure!(!groups.is_empty(), "layout needs at least one group");
        let mut off = 0;
        for g in &groups {
            anyhow::ensure!(
                g.offset == off,
                "group '{}' starts at {}, expected {off}",
                g.name,
                g.offset
            );
            anyhow::ensure!(g.len > 0, "group '{}' is empty", g.name);
            off += g.len;
        }
        anyhow::ensure!(off == n, "groups cover {off} of {n} params");
        anyhow::ensure!(
            n as u64 <= (crate::compress::encode::MAX_INDEX as u64) + 1,
            "N={n} exceeds the 28-bit index space"
        );
        Ok(Layout { n, groups })
    }

    /// From a manifest model entry.
    pub fn from_manifest(entry: &crate::runtime::ModelEntry) -> anyhow::Result<Layout> {
        Layout::new(
            entry.n_params,
            entry
                .groups
                .iter()
                .map(|g| ParamGroup {
                    name: g.name.clone(),
                    offset: g.offset,
                    len: g.len,
                })
                .collect(),
        )
    }

    /// A synthetic layout with fixed-size groups (tests and benches).
    pub fn uniform(n: usize, group_size: usize) -> Layout {
        assert!(n > 0 && group_size > 0);
        let mut groups = Vec::new();
        let mut off = 0;
        let mut k = 0;
        while off < n {
            let len = group_size.min(n - off);
            groups.push(ParamGroup {
                name: format!("g{k}"),
                offset: off,
                len,
            });
            off += len;
            k += 1;
        }
        Layout::new(n, groups).expect("uniform layout is valid")
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn groups(&self) -> &[ParamGroup] {
        &self.groups
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_layout_partitions() {
        let l = Layout::uniform(10, 4);
        assert_eq!(l.n_groups(), 3);
        assert_eq!(l.groups()[2].len, 2);
        assert_eq!(l.n(), 10);
    }

    #[test]
    fn rejects_gap_and_overlap() {
        let bad = vec![
            ParamGroup { name: "a".into(), offset: 0, len: 4 },
            ParamGroup { name: "b".into(), offset: 5, len: 5 },
        ];
        assert!(Layout::new(10, bad).is_err());
        let overlap = vec![
            ParamGroup { name: "a".into(), offset: 0, len: 6 },
            ParamGroup { name: "b".into(), offset: 4, len: 6 },
        ];
        assert!(Layout::new(10, overlap).is_err());
    }

    #[test]
    fn rejects_28bit_overflow() {
        // A fake huge layout must be rejected (index field is 28 bits).
        let groups = vec![ParamGroup {
            name: "w".into(),
            offset: 0,
            len: 1 << 29,
        }];
        assert!(Layout::new(1 << 29, groups).is_err());
    }
}
