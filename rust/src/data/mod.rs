//! Data pipeline (S11): synthetic datasets and deterministic sharding.
//!
//! The paper trains on CIFAR-10 and ImageNet; this testbed has neither
//! (DESIGN.md §Substitutions), so we synthesize class-conditional
//! datasets with a fixed seed: each class owns a random template
//! pattern, a sample is `signal·template + noise·N(0,1)`. This yields a
//! learnable-but-not-trivial classification problem whose gradient
//! statistics (large early gradients, shrinking ambiguous ones later)
//! exercise the same codec behaviour the real datasets do.
//!
//! For the LM workload, tokens come from a seeded order-1 Markov chain
//! with sparse transitions — learnable next-token structure.

pub mod shard;

use crate::util::rng::Pcg32;

/// An in-memory synthetic image classification dataset (flattened
/// samples, row-major `[n, sample_elems]`).
pub struct ImageDataset {
    pub samples: Vec<f32>,
    pub labels: Vec<i32>,
    pub sample_elems: usize,
    pub n_classes: usize,
}

impl ImageDataset {
    /// 1-D convenience wrapper (MLP-style flat inputs).
    pub fn synth(
        seed: u64,
        n: usize,
        sample_elems: usize,
        n_classes: usize,
        signal: f32,
    ) -> ImageDataset {
        Self::synth_split(seed, 0, n, &[sample_elems], n_classes, signal)
    }

    /// Generate `n` samples of shape `sample_shape` over `n_classes`
    /// classes. `signal` controls separability (≈1.0 is comfortably
    /// learnable for the tiny models; lower is harder).
    ///
    /// Class templates are **spatially low-frequency**: drawn on a 4×
    /// coarser grid along each leading (spatial) dimension and
    /// nearest-upsampled. High-frequency (iid-pixel) templates would be
    /// invisible to the conv models — shared 3×3 kernels + pooling + GAP
    /// average out pixel-level noise, so the task must put class signal
    /// in low spatial frequencies, as natural images do.
    ///
    /// Templates depend only on `seed`; the per-sample noise stream
    /// additionally depends on `split`, so `synth_split(seed, 0, ..)`
    /// (train) and `synth_split(seed, 1, ..)` (test) are disjoint draws
    /// from the SAME underlying task.
    pub fn synth_split(
        seed: u64,
        split: u64,
        n: usize,
        sample_shape: &[usize],
        n_classes: usize,
        signal: f32,
    ) -> ImageDataset {
        let sample_elems: usize = sample_shape.iter().product::<usize>().max(1);
        // Spatial dims = all but the trailing channel dim (for [H,W,C]);
        // for flat [D] treat D as the single spatial dim.
        let (h, w, c) = match sample_shape {
            [h, w, c] => (*h, *w, *c),
            [d] => (1usize, *d, 1usize),
            other => {
                let d: usize = other.iter().product();
                (1, d, 1)
            }
        };
        const F: usize = 4; // upsampling factor
        let (h4, w4) = (h.div_ceil(F), w.div_ceil(F));

        // Templates from `seed` only — both splits share the task.
        let mut trng = Pcg32::new(seed, 0xDA7A);
        let mut coarse = vec![0.0f32; n_classes * h4 * w4 * c];
        for t in coarse.iter_mut() {
            *t = trng.next_normal();
        }
        let mut templates = vec![0.0f32; n_classes * sample_elems];
        for y in 0..n_classes {
            for i in 0..h {
                for j in 0..w {
                    for ch in 0..c {
                        let src = ((y * h4 + i / F) * w4 + j / F) * c + ch;
                        templates[y * sample_elems + (i * w + j) * c + ch] = coarse[src];
                    }
                }
            }
        }

        let mut rng = Pcg32::new(seed ^ (split.wrapping_mul(0x9E3779B9)), 0xDA7B ^ split);
        let mut samples = vec![0.0f32; n * sample_elems];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let y = rng.next_bounded(n_classes as u32) as usize;
            labels[i] = y as i32;
            let tpl = &templates[y * sample_elems..(y + 1) * sample_elems];
            let row = &mut samples[i * sample_elems..(i + 1) * sample_elems];
            for (k, r) in row.iter_mut().enumerate() {
                *r = signal * tpl[k] + rng.next_normal();
            }
        }
        ImageDataset {
            samples,
            labels,
            sample_elems,
            n_classes,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.samples[i * self.sample_elems..(i + 1) * self.sample_elems]
    }
}

/// Synthetic token corpus from a sparse order-1 Markov chain.
pub struct TokenDataset {
    pub sequences: Vec<i32>,
    pub seq_len: usize,
    pub vocab: usize,
    n_seqs: usize,
}

impl TokenDataset {
    /// `n_seqs` sequences of `seq_len` tokens over `vocab` symbols.
    /// Each symbol has `branching` likely successors — low enough
    /// entropy that the LM loss visibly falls below ln(vocab).
    pub fn synth(seed: u64, n_seqs: usize, seq_len: usize, vocab: usize) -> TokenDataset {
        Self::synth_split(seed, 0, n_seqs, seq_len, vocab)
    }

    /// Same Markov chain (from `seed`), disjoint sequences per `split`.
    pub fn synth_split(
        seed: u64,
        split: u64,
        n_seqs: usize,
        seq_len: usize,
        vocab: usize,
    ) -> TokenDataset {
        let branching = 4usize;
        // Chain from `seed` only — shared task across splits.
        let mut crng = Pcg32::new(seed, 0x70C5);
        // successors[v] = the `branching` tokens v transitions to.
        let successors: Vec<Vec<u32>> = (0..vocab)
            .map(|_| {
                (0..branching)
                    .map(|_| crng.next_bounded(vocab as u32))
                    .collect()
            })
            .collect();
        let mut rng = Pcg32::new(seed ^ (split.wrapping_mul(0x9E3779B9)), 0x70C6 ^ split);
        let mut sequences = vec![0i32; n_seqs * seq_len];
        for s in 0..n_seqs {
            let mut tok = rng.next_bounded(vocab as u32);
            for t in 0..seq_len {
                sequences[s * seq_len + t] = tok as i32;
                let succ = &successors[tok as usize];
                // 90% follow the chain, 10% jump anywhere.
                tok = if rng.next_bool(0.9) {
                    succ[rng.next_bounded(branching as u32) as usize]
                } else {
                    rng.next_bounded(vocab as u32)
                };
            }
        }
        TokenDataset {
            sequences,
            seq_len,
            vocab,
            n_seqs,
        }
    }

    pub fn len(&self) -> usize {
        self.n_seqs
    }

    pub fn is_empty(&self) -> bool {
        self.n_seqs == 0
    }

    pub fn sequence(&self, i: usize) -> &[i32] {
        &self.sequences[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_synth_is_deterministic() {
        let a = ImageDataset::synth(7, 100, 48, 10, 1.0);
        let b = ImageDataset::synth(7, 100, 48, 10, 1.0);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.labels, b.labels);
        let c = ImageDataset::synth(8, 100, 48, 10, 1.0);
        assert_ne!(a.samples, c.samples);
    }

    #[test]
    fn image_labels_cover_classes() {
        let d = ImageDataset::synth(1, 1000, 16, 10, 1.0);
        let mut seen = [false; 10];
        for &y in &d.labels {
            assert!((0..10).contains(&y));
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn same_class_samples_correlate_more_than_cross_class() {
        let d = ImageDataset::synth(3, 400, 64, 4, 1.5);
        let dot = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>() / a.len() as f32
        };
        let (mut same, mut same_n, mut cross, mut cross_n) = (0f64, 0u32, 0f64, 0u32);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let c = dot(d.sample(i), d.sample(j)) as f64;
                if d.labels[i] == d.labels[j] {
                    same += c;
                    same_n += 1;
                } else {
                    cross += c;
                    cross_n += 1;
                }
            }
        }
        assert!(same / same_n as f64 > cross / cross_n as f64 + 0.3);
    }

    #[test]
    fn token_synth_shapes_and_range() {
        let d = TokenDataset::synth(5, 32, 64, 256);
        assert_eq!(d.len(), 32);
        assert_eq!(d.sequence(0).len(), 64);
        assert!(d.sequences.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn token_chain_has_structure() {
        // Bigram entropy must be far below uniform: count distinct
        // successors per token — with branching 4 + 10% noise it should
        // be much smaller than vocab.
        let d = TokenDataset::synth(11, 64, 128, 64);
        let mut succ: Vec<std::collections::BTreeSet<i32>> = vec![Default::default(); 64];
        for s in 0..d.len() {
            let seq = d.sequence(s);
            for w in seq.windows(2) {
                succ[w[0] as usize].insert(w[1]);
            }
        }
        let avg: f64 = succ.iter().map(|s| s.len() as f64).sum::<f64>() / 64.0;
        assert!(avg < 32.0, "avg distinct successors {avg} too uniform");
    }
}
