//! Deterministic per-worker data sharding and batch iteration.
//!
//! Data-parallel SGD: worker w of p sees the samples with
//! `index % p == w` (interleaved shards, so class balance survives any
//! dataset ordering). Each epoch reshuffles *within* the shard with a
//! seeded PRNG — every run of the same config touches identical batches
//! in identical order, which the reproduction experiments rely on.

use crate::util::rng::Pcg32;

/// A worker's view of a dataset: shard indices + epoch shuffling.
pub struct Shard {
    indices: Vec<usize>,
    cursor: usize,
    epoch: u64,
    rng: Pcg32,
}

impl Shard {
    pub fn new(dataset_len: usize, worker: usize, workers: usize, seed: u64) -> Shard {
        assert!(worker < workers);
        let indices: Vec<usize> = (worker..dataset_len).step_by(workers).collect();
        let mut shard = Shard {
            indices,
            cursor: 0,
            epoch: 0,
            rng: Pcg32::new(seed ^ 0x5AAD, worker as u64),
        };
        shard.shuffle();
        shard
    }

    fn shuffle(&mut self) {
        let mut rng = self.rng.split(self.epoch);
        rng.shuffle(&mut self.indices);
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next batch of `b` dataset indices; wraps to a new shuffled epoch
    /// when exhausted (batches never straddle epochs).
    pub fn next_batch(&mut self, b: usize) -> Vec<usize> {
        assert!(b <= self.indices.len(), "batch larger than shard");
        if self.cursor + b > self.indices.len() {
            self.epoch += 1;
            self.cursor = 0;
            self.shuffle();
        }
        let out = self.indices[self.cursor..self.cursor + b].to_vec();
        self.cursor += b;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_partition_dataset() {
        let p = 4;
        let n = 103;
        let mut seen = vec![0u32; n];
        for w in 0..p {
            let s = Shard::new(n, w, p, 0);
            // Collect the shard's index set via one full epoch.
            let mut sh = s;
            let len = sh.len();
            for idx in sh.next_batch(len) {
                seen[idx] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "partition violated: {seen:?}");
    }

    #[test]
    fn shard_sizes_balanced() {
        let p = 8;
        let n = 1000;
        let sizes: Vec<usize> = (0..p).map(|w| Shard::new(n, w, p, 0).len()).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Shard::new(64, 1, 4, 42);
        let mut b = Shard::new(64, 1, 4, 42);
        for _ in 0..10 {
            assert_eq!(a.next_batch(4), b.next_batch(4));
        }
        let mut c = Shard::new(64, 1, 4, 43);
        let mut differs = false;
        for _ in 0..10 {
            differs |= a.next_batch(4) != c.next_batch(4);
        }
        assert!(differs, "different seeds must differ");
    }

    #[test]
    fn epoch_advances_and_reshuffles() {
        let mut s = Shard::new(16, 0, 2, 7); // shard size 8
        let e0: Vec<usize> = (0..2).flat_map(|_| s.next_batch(4)).collect();
        assert_eq!(s.epoch(), 0);
        let e1: Vec<usize> = (0..2).flat_map(|_| s.next_batch(4)).collect();
        assert_eq!(s.epoch(), 1);
        let mut a = e0.clone();
        let mut b = e1.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "same index set per epoch");
        assert_ne!(e0, e1, "order reshuffled");
    }

    #[test]
    fn batches_never_repeat_within_epoch() {
        let mut s = Shard::new(40, 0, 1, 3);
        let batch_elems: Vec<usize> = (0..4).flat_map(|_| s.next_batch(10)).collect();
        let mut sorted = batch_elems.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 40);
    }
}
