//! Experiment configuration (S15): defaults, named presets, CLI
//! overrides, and JSON round-trip.
//!
//! A `TrainConfig` fully determines a run (model + codec + optimizer +
//! schedule + data + seeds), so the table harnesses are just lists of
//! configs. Configs serialize to JSON for the record in EXPERIMENTS.md
//! and load back for replays.

use crate::compress::CodecSpec;
use crate::fabric::FabricConfig;
use crate::optim::LrSchedule;
use crate::util::cli::Args;
use crate::util::json::{num, obj, s, Json};

/// What the trainer does when the fault plan kills a worker mid-run
/// (`--on-crash`; see docs/FAULTS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CrashPolicy {
    /// Steps complete over the survivor set with the aggregation
    /// renormalized to the live count; the dead worker's codec
    /// residual is discarded (rebuilt from scratch on rejoin). Training
    /// math degrades measurably.
    #[default]
    Renorm,
    /// Every worker crash must rejoin; the rejoining peer replays the
    /// missed work from the replicated state and flushes the residual
    /// back in, so training math stays bit-identical to the fault-free
    /// run and only simulated time degrades.
    FlushRejoin,
}

impl CrashPolicy {
    pub fn parse(s: &str) -> anyhow::Result<CrashPolicy> {
        match s {
            "renorm" => Ok(CrashPolicy::Renorm),
            "flush-rejoin" => Ok(CrashPolicy::FlushRejoin),
            other => anyhow::bail!("unknown crash policy '{other}' (renorm|flush-rejoin)"),
        }
    }

    /// Canonical string form (parses back).
    pub fn label(&self) -> &'static str {
        match self {
            CrashPolicy::Renorm => "renorm",
            CrashPolicy::FlushRejoin => "flush-rejoin",
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub codec: CodecSpec,
    pub optimizer: String,
    pub schedule: LrSchedule,
    pub weight_decay: f32,
    pub steps: u64,
    pub seed: u64,
    pub eval_every: u64,
    pub log_every: u64,
    /// Training-set size (synthetic).
    pub train_size: usize,
    /// Held-out eval-set size.
    pub test_size: usize,
    /// Class separability of the synthetic data.
    pub signal: f32,
    /// Cross-check that all workers decode identical updates (costly:
    /// decodes P× twice; on by default in tests, off in benches). With
    /// `codec_threads > 1` the check cross-validates the parallel
    /// engine against the serial decode every step.
    pub verify_sync: bool,
    /// Codec engine threads: 0 = auto (available parallelism), 1 = the
    /// exact serial path, N > 1 = parallel sharded encode/decode.
    pub codec_threads: usize,
    /// Cluster/network model for the simulated-wall-clock report
    /// (topology, link bandwidth/latency/jitter, stragglers, faults).
    pub fabric: FabricConfig,
    /// Degradation policy when the fault plan kills a worker.
    pub on_crash: CrashPolicy,
    /// Tensor-fusion threshold for the bucketed comm pipeline, dense
    /// bytes (`--bucket-bytes`; 0 = one bucket spanning the model).
    /// Buckets fill greedily in reverse layer order — see
    /// docs/PIPELINE.md.
    pub bucket_bytes: usize,
    /// Schedule bucket gathers overlapped with compute/encode on the
    /// fabric's event clock (`--overlap`). Trained parameters are
    /// bit-identical either way; only the simulated step time moves.
    pub overlap: bool,
    /// Close the compression loop (`--adaptive`): a per-bucket
    /// controller (`compress::controller`) adjusts the codec's knob
    /// (ζ/π/τ) from fabric telemetry between steps. Off = static,
    /// bit-identical to pre-adaptive behavior.
    pub adaptive: bool,
    /// Controller pressure target (`--adaptive-target`; 1.0 = each
    /// bucket's comm exactly fills its fair share of compute).
    pub adaptive_target: f64,
}

impl TrainConfig {
    /// Per-model defaults tuned for the scaled synthetic workloads.
    pub fn defaults(model: &str) -> TrainConfig {
        let (steps, lr_sched, optimizer) = match model {
            "mlp" => (200, "const:0.02", "momentum"),
            "vgg_tiny" => (300, "step:0.003,0.5,150", "momentum"),
            "vgg_cifar" => (200, "step:0.003,0.5,100", "momentum"),
            "resnet_mini" => (300, "step:0.001,0.5,150", "momentum"),
            "transformer" => (300, "const:0.002", "adam"),
            _ => (200, "const:0.05", "momentum"),
        };
        TrainConfig {
            model: model.to_string(),
            codec: CodecSpec::Vgc {
                alpha: 1.5,
                zeta: 0.999,
            },
            optimizer: optimizer.into(),
            schedule: LrSchedule::parse(lr_sched).unwrap(),
            weight_decay: 5e-4,
            steps,
            seed: 0,
            eval_every: 50,
            log_every: 10,
            train_size: 4096,
            test_size: 1024,
            signal: 1.0,
            verify_sync: false,
            codec_threads: 0,
            fabric: FabricConfig::default(),
            on_crash: CrashPolicy::Renorm,
            bucket_bytes: 0,
            overlap: false,
            adaptive: false,
            adaptive_target: 1.0,
        }
    }

    /// The engine width `codec_threads` resolves to (0 = auto).
    pub fn resolved_codec_threads(&self) -> usize {
        if self.codec_threads == 0 {
            crate::util::threadpool::ThreadPool::available()
        } else {
            self.codec_threads
        }
    }

    /// Apply CLI flag overrides.
    pub fn override_from(mut self, args: &Args) -> anyhow::Result<TrainConfig> {
        if let Some(c) = args.get("codec") {
            self.codec = CodecSpec::parse(c)?;
        }
        if let Some(o) = args.get("optimizer") {
            self.optimizer = o.to_string();
        }
        if let Some(l) = args.get("lr") {
            self.schedule = LrSchedule::parse(l)?;
        }
        self.weight_decay = args.parse_or("weight-decay", self.weight_decay)?;
        self.steps = args.parse_or("steps", self.steps)?;
        self.seed = args.parse_or("seed", self.seed)?;
        self.eval_every = args.parse_or("eval-every", self.eval_every)?;
        self.log_every = args.parse_or("log-every", self.log_every)?;
        self.train_size = args.parse_or("train-size", self.train_size)?;
        self.test_size = args.parse_or("test-size", self.test_size)?;
        self.signal = args.parse_or("signal", self.signal)?;
        if args.has("verify-sync") {
            self.verify_sync = true;
        }
        self.codec_threads = args.parse_or("codec-threads", self.codec_threads)?;
        if let Some(p) = args.get("on-crash") {
            self.on_crash = CrashPolicy::parse(p)?;
        }
        self.bucket_bytes = args.parse_or("bucket-bytes", self.bucket_bytes)?;
        if args.has("overlap") {
            self.overlap = true;
        }
        if args.has("adaptive") {
            self.adaptive = true;
        }
        self.adaptive_target = args.parse_or("adaptive-target", self.adaptive_target)?;
        anyhow::ensure!(
            self.adaptive_target > 0.0,
            "--adaptive-target must be positive"
        );
        self.fabric = self.fabric.override_from(args)?;
        Ok(self)
    }

    /// Serialize for the experiment record.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("codec", s(&codec_str(&self.codec))),
            ("optimizer", s(&self.optimizer)),
            ("schedule", s(&schedule_str(&self.schedule))),
            ("weight_decay", num(self.weight_decay as f64)),
            ("steps", num(self.steps as f64)),
            ("seed", num(self.seed as f64)),
            ("train_size", num(self.train_size as f64)),
            ("test_size", num(self.test_size as f64)),
            ("signal", num(self.signal as f64)),
            ("codec_threads", num(self.codec_threads as f64)),
            ("on_crash", s(self.on_crash.label())),
            ("bucket_bytes", num(self.bucket_bytes as f64)),
            ("overlap", Json::Bool(self.overlap)),
            ("adaptive", Json::Bool(self.adaptive)),
            ("adaptive_target", num(self.adaptive_target)),
            ("fabric", self.fabric.to_json()),
        ])
    }

    /// Load from a JSON config file written by `to_json`.
    pub fn from_json(j: &Json) -> anyhow::Result<TrainConfig> {
        let model = j.expect("model")?.as_str()?;
        let mut cfg = TrainConfig::defaults(model);
        cfg.codec = CodecSpec::parse(j.expect("codec")?.as_str()?)?;
        cfg.optimizer = j.expect("optimizer")?.as_str()?.to_string();
        cfg.schedule = LrSchedule::parse(j.expect("schedule")?.as_str()?)?;
        cfg.weight_decay = j.expect("weight_decay")?.as_f64()? as f32;
        cfg.steps = j.expect("steps")?.as_usize()? as u64;
        cfg.seed = j.expect("seed")?.as_usize()? as u64;
        cfg.train_size = j.expect("train_size")?.as_usize()?;
        cfg.test_size = j.expect("test_size")?.as_usize()?;
        cfg.signal = j.expect("signal")?.as_f64()? as f32;
        // Absent in configs recorded before the engine existed.
        if let Some(t) = j.get("codec_threads") {
            cfg.codec_threads = t.as_usize()?;
        }
        // Absent in configs recorded before crash policies existed.
        if let Some(p) = j.get("on_crash") {
            cfg.on_crash = CrashPolicy::parse(p.as_str()?)?;
        }
        // Absent in configs recorded before the overlap pipeline.
        if let Some(b) = j.get("bucket_bytes") {
            cfg.bucket_bytes = b.as_usize()?;
        }
        if let Some(Json::Bool(o)) = j.get("overlap") {
            cfg.overlap = *o;
        }
        // Absent in configs recorded before the adaptive controller.
        if let Some(Json::Bool(a)) = j.get("adaptive") {
            cfg.adaptive = *a;
        }
        if let Some(t) = j.get("adaptive_target") {
            cfg.adaptive_target = t.as_f64()?;
        }
        // Absent in configs recorded before the fabric existed.
        if let Some(f) = j.get("fabric") {
            cfg.fabric = FabricConfig::from_json(f)?;
        }
        Ok(cfg)
    }
}

/// Canonical string form of a codec spec (parses back via
/// `CodecSpec::parse`).
pub fn codec_str(c: &CodecSpec) -> String {
    match c {
        CodecSpec::None => "none".into(),
        CodecSpec::Vgc { alpha, zeta } => format!("vgc:alpha={alpha},zeta={zeta}"),
        CodecSpec::VgcCompact { alpha, zeta } => {
            format!("vgc:alpha={alpha},zeta={zeta},index=gamma")
        }
        CodecSpec::Strom { tau } => format!("strom:tau={tau}"),
        CodecSpec::Hybrid { tau, alpha, zeta } => {
            format!("hybrid:tau={tau},alpha={alpha},zeta={zeta}")
        }
        CodecSpec::Qsgd { bits, bucket } => format!("qsgd:bits={bits},d={bucket}"),
        CodecSpec::TernGrad => "terngrad".into(),
        CodecSpec::OneBit => "onebit".into(),
        CodecSpec::Adaptive { pi } => format!("adaptive:pi={pi}"),
    }
}

/// Canonical string form of a schedule (parses back).
pub fn schedule_str(sch: &LrSchedule) -> String {
    match sch {
        LrSchedule::Constant { lr } => format!("const:{lr}"),
        LrSchedule::StepDecay { lr, factor, every } => {
            format!("step:{lr},{factor},{every}")
        }
        LrSchedule::Warmup { lr, warmup } => format!("warmup:{lr},{warmup}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_per_model() {
        let c = TrainConfig::defaults("transformer");
        assert_eq!(c.optimizer, "adam");
        let v = TrainConfig::defaults("vgg_tiny");
        assert_eq!(v.optimizer, "momentum");
    }

    #[test]
    fn cli_overrides_apply() {
        let raw: Vec<String> = [
            "--codec",
            "strom:tau=0.1",
            "--steps",
            "42",
            "--optimizer",
            "adam",
            "--lr",
            "const:0.001",
            "--verify-sync",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &["verify-sync"]).unwrap();
        let cfg = TrainConfig::defaults("mlp").override_from(&args).unwrap();
        assert_eq!(cfg.codec, CodecSpec::Strom { tau: 0.1 });
        assert_eq!(cfg.steps, 42);
        assert_eq!(cfg.optimizer, "adam");
        assert!(cfg.verify_sync);
    }

    #[test]
    fn codec_threads_override_and_resolution() {
        let raw: Vec<String> = ["--codec-threads", "3"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&raw, &[]).unwrap();
        let cfg = TrainConfig::defaults("mlp").override_from(&args).unwrap();
        assert_eq!(cfg.codec_threads, 3);
        assert_eq!(cfg.resolved_codec_threads(), 3);
        // Default is auto: resolves to available parallelism (>= 1).
        let auto = TrainConfig::defaults("mlp");
        assert_eq!(auto.codec_threads, 0);
        assert!(auto.resolved_codec_threads() >= 1);
        // Round-trips through JSON.
        let back =
            TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.codec_threads, 3);
    }

    #[test]
    fn bad_codec_flag_is_loud() {
        let raw = vec!["--codec".to_string(), "nope:x=1".to_string()];
        let args = Args::parse(&raw, &[]).unwrap();
        assert!(TrainConfig::defaults("mlp").override_from(&args).is_err());
    }

    #[test]
    fn json_roundtrip_preserves_config() {
        let mut cfg = TrainConfig::defaults("vgg_tiny");
        cfg.codec = CodecSpec::Hybrid {
            tau: 0.01,
            alpha: 2.0,
            zeta: 0.999,
        };
        cfg.steps = 77;
        let j = cfg.to_json();
        let back = TrainConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.codec, cfg.codec);
        assert_eq!(back.steps, 77);
        assert_eq!(back.model, "vgg_tiny");
    }

    #[test]
    fn fabric_overrides_and_json_roundtrip() {
        let raw: Vec<String> = [
            "--topology",
            "star",
            "--bandwidth-gbps",
            "10",
            "--stragglers",
            "0:3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&raw, &[]).unwrap();
        let cfg = TrainConfig::defaults("mlp").override_from(&args).unwrap();
        assert_eq!(cfg.fabric.topology, crate::fabric::TopologyKind::Star);
        assert_eq!(cfg.fabric.link.bandwidth_gbps, 10.0);
        assert_eq!(cfg.fabric.stragglers.len(), 1);

        let back =
            TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.fabric, cfg.fabric);
    }

    #[test]
    fn crash_policy_flag_and_json_roundtrip() {
        let raw: Vec<String> = ["--on-crash", "flush-rejoin", "--faults", "crash:1@5+3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &[]).unwrap();
        let cfg = TrainConfig::defaults("mlp").override_from(&args).unwrap();
        assert_eq!(cfg.on_crash, CrashPolicy::FlushRejoin);
        assert_eq!(cfg.fabric.faults.crashes.len(), 1);
        let back =
            TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.on_crash, CrashPolicy::FlushRejoin);
        assert_eq!(back.fabric.faults, cfg.fabric.faults);
        // Defaults and bad values.
        assert_eq!(TrainConfig::defaults("mlp").on_crash, CrashPolicy::Renorm);
        assert!(CrashPolicy::parse("explode").is_err());
        for p in [CrashPolicy::Renorm, CrashPolicy::FlushRejoin] {
            assert_eq!(CrashPolicy::parse(p.label()).unwrap(), p);
        }
    }

    #[test]
    fn pipeline_flags_and_json_roundtrip() {
        let raw: Vec<String> = ["--bucket-bytes", "65536", "--overlap"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["overlap"]).unwrap();
        let cfg = TrainConfig::defaults("mlp").override_from(&args).unwrap();
        assert_eq!(cfg.bucket_bytes, 65536);
        assert!(cfg.overlap);
        let back =
            TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.bucket_bytes, 65536);
        assert!(back.overlap);
        // Configs recorded before the pipeline existed still load.
        let legacy = TrainConfig::defaults("mlp").to_json().to_string();
        let stripped = legacy
            .replace("\"bucket_bytes\":0,", "")
            .replace("\"overlap\":false,", "");
        let old = TrainConfig::from_json(&Json::parse(&stripped).unwrap()).unwrap();
        assert_eq!(old.bucket_bytes, 0);
        assert!(!old.overlap);
    }

    #[test]
    fn adaptive_flags_and_json_roundtrip() {
        let raw: Vec<String> = ["--adaptive", "--adaptive-target", "1.5"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&raw, &["adaptive"]).unwrap();
        let cfg = TrainConfig::defaults("mlp").override_from(&args).unwrap();
        assert!(cfg.adaptive);
        assert_eq!(cfg.adaptive_target, 1.5);
        let back =
            TrainConfig::from_json(&Json::parse(&cfg.to_json().to_string()).unwrap()).unwrap();
        assert!(back.adaptive);
        assert_eq!(back.adaptive_target, 1.5);
        // Defaults: off, target 1.0.
        let d = TrainConfig::defaults("mlp");
        assert!(!d.adaptive);
        assert_eq!(d.adaptive_target, 1.0);
        // Configs recorded before the controller existed still load.
        let legacy = d.to_json().to_string();
        let stripped = legacy
            .replace("\"adaptive\":false,", "")
            .replace("\"adaptive_target\":1,", "");
        let old = TrainConfig::from_json(&Json::parse(&stripped).unwrap()).unwrap();
        assert!(!old.adaptive);
        // A zero target is a config error.
        let raw: Vec<String> = ["--adaptive-target", "0"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&raw, &[]).unwrap();
        assert!(TrainConfig::defaults("mlp").override_from(&args).is_err());
    }

    #[test]
    fn codec_str_parses_back() {
        for c in [
            CodecSpec::None,
            CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
            CodecSpec::Strom { tau: 0.01 },
            CodecSpec::Hybrid { tau: 0.1, alpha: 2.0, zeta: 0.999 },
            CodecSpec::Qsgd { bits: 2, bucket: 128 },
            CodecSpec::TernGrad,
            CodecSpec::OneBit,
            CodecSpec::Adaptive { pi: 0.05 },
            CodecSpec::VgcCompact { alpha: 1.5, zeta: 0.999 },
        ] {
            assert_eq!(CodecSpec::parse(&codec_str(&c)).unwrap(), c);
        }
    }
}
