//! Artifact manifest: the contract between the python AOT path and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! lowered HLO module (shapes, dtypes, worker/batch geometry), the
//! initial parameter blobs, and the flat-layout group table that defines
//! the paper's per-weight-matrix quantization scopes (`M_k`, Sec. 4.2).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One named tensor's span in the flat parameter vector. Quantization
/// groups (Sec. 4.2) are exactly these spans.
#[derive(Debug, Clone, PartialEq)]
pub struct Group {
    pub name: String,
    pub offset: usize,
    pub len: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float32" => Ok(Dtype::F32),
            "int32" => Ok(Dtype::I32),
            other => anyhow::bail!("unsupported dtype in manifest: {other}"),
        }
    }
}

/// What the eval artifact returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalKind {
    /// `[eval_batch, n_classes]` logits (classifiers).
    Logits,
    /// Scalar mean loss (language models).
    Loss,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    pub n_params: usize,
    pub workers: usize,
    pub batch: usize,
    pub chunk: usize,
    pub eval_batch: usize,
    pub n_classes: usize,
    pub sample_shape: Vec<usize>,
    pub sample_dtype: Dtype,
    pub grad_hlo: String,
    pub eval_hlo: String,
    pub eval_kind: EvalKind,
    pub params_bin: String,
    pub groups: Vec<Group>,
    pub seed: u64,
}

impl ModelEntry {
    /// Elements in one input sample.
    pub fn sample_elems(&self) -> usize {
        self.sample_shape.iter().product::<usize>().max(1)
    }

    /// Dims of the grad artifact's `xs` input: `[P, B, *sample]`.
    pub fn xs_dims(&self) -> Vec<i64> {
        let mut dims = vec![self.workers as i64, self.batch as i64];
        dims.extend(self.sample_shape.iter().map(|&d| d as i64));
        dims
    }
}

#[derive(Debug, Clone)]
pub struct MomentsBenchEntry {
    pub b: usize,
    pub n: usize,
    pub hlo: String,
}

#[derive(Debug, Clone)]
pub struct CriterionEntry {
    pub n: usize,
    pub hlo: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub models: Vec<ModelEntry>,
    pub moments_bench: Vec<MomentsBenchEntry>,
    pub criterion: Vec<CriterionEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let version = root.expect("format_version")?.as_usize()?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");

        let mut models = Vec::new();
        for m in root.expect("models")?.as_arr()? {
            models.push(parse_model(m)?);
        }
        let shared = root.expect("shared")?;
        let mut moments_bench = Vec::new();
        for e in shared.expect("moments_bench")?.as_arr()? {
            moments_bench.push(MomentsBenchEntry {
                b: e.expect("b")?.as_usize()?,
                n: e.expect("n")?.as_usize()?,
                hlo: e.expect("hlo")?.as_str()?.to_string(),
            });
        }
        let mut criterion = Vec::new();
        for e in shared.expect("criterion")?.as_arr()? {
            criterion.push(CriterionEntry {
                n: e.expect("n")?.as_usize()?,
                hlo: e.expect("hlo")?.as_str()?.to_string(),
            });
        }

        Ok(Manifest {
            dir,
            fingerprint: root.expect("fingerprint")?.as_str()?.to_string(),
            models,
            moments_bench,
            criterion,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.iter().find(|m| m.name == name).ok_or_else(|| {
            let have: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
            anyhow::anyhow!("model '{name}' not in manifest; available: {have:?}")
        })
    }

    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Load a `.params.bin` blob (little-endian f32).
    pub fn load_params(&self, entry: &ModelEntry) -> Result<Vec<f32>> {
        let path = self.path_of(&entry.params_bin);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            bytes.len() == entry.n_params * 4,
            "params blob {path:?} has {} bytes, expected {}",
            bytes.len(),
            entry.n_params * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn parse_model(m: &Json) -> Result<ModelEntry> {
    let groups_json = m.expect("groups")?.as_arr()?;
    let mut groups = Vec::with_capacity(groups_json.len());
    for g in groups_json {
        groups.push(Group {
            name: g.expect("name")?.as_str()?.to_string(),
            offset: g.expect("offset")?.as_usize()?,
            len: g.expect("len")?.as_usize()?,
        });
    }
    let n_params = m.expect("n_params")?.as_usize()?;
    // Validate the group table partitions [0, N): the quantizer trusts it.
    let mut off = 0;
    for g in &groups {
        anyhow::ensure!(
            g.offset == off && g.len > 0,
            "group table not contiguous at {}",
            g.name
        );
        off += g.len;
    }
    anyhow::ensure!(off == n_params, "groups cover {off}, expected {n_params}");

    let eval_kind = match m.expect("eval_kind")?.as_str()? {
        "logits" => EvalKind::Logits,
        "loss" => EvalKind::Loss,
        other => anyhow::bail!("unknown eval_kind {other}"),
    };

    Ok(ModelEntry {
        name: m.expect("name")?.as_str()?.to_string(),
        kind: m.expect("kind")?.as_str()?.to_string(),
        n_params,
        workers: m.expect("workers")?.as_usize()?,
        batch: m.expect("batch")?.as_usize()?,
        chunk: m.expect("chunk")?.as_usize()?,
        eval_batch: m.expect("eval_batch")?.as_usize()?,
        n_classes: m.expect("n_classes")?.as_usize()?,
        sample_shape: m
            .expect("sample_shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<_>>()?,
        sample_dtype: Dtype::parse(m.expect("sample_dtype")?.as_str()?)?,
        grad_hlo: m.expect("grad_hlo")?.as_str()?.to_string(),
        eval_hlo: m.expect("eval_hlo")?.as_str()?.to_string(),
        eval_kind,
        params_bin: m.expect("params_bin")?.as_str()?.to_string(),
        groups,
        seed: m.expect("seed")?.as_usize()? as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
          "format_version": 1,
          "fingerprint": "abc123",
          "models": [{
            "name": "m", "kind": "classifier", "n_params": 10,
            "workers": 2, "batch": 4, "chunk": 2, "eval_batch": 8,
            "n_classes": 3, "sample_shape": [5], "sample_dtype": "float32",
            "label_dtype": "int32",
            "grad_hlo": "m.grad.hlo.txt", "eval_hlo": "m.fwd.hlo.txt",
            "eval_kind": "logits", "params_bin": "m.params.bin",
            "groups": [{"name": "a", "offset": 0, "len": 6},
                        {"name": "b", "offset": 6, "len": 4}],
            "seed": 0
          }],
          "shared": {"moments_bench": [], "criterion": []}
        }"#
        .to_string()
    }

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join("vgc_manifest_ok");
        write_manifest(&dir, &fake_manifest_json());
        let man = Manifest::load(&dir).unwrap();
        let m = man.model("m").unwrap();
        assert_eq!(m.n_params, 10);
        assert_eq!(m.xs_dims(), vec![2, 4, 5]);
        assert_eq!(m.sample_elems(), 5);
        assert!(man.model("nope").is_err());
    }

    #[test]
    fn rejects_non_contiguous_groups() {
        let dir = std::env::temp_dir().join("vgc_manifest_bad");
        let bad = fake_manifest_json().replace("\"offset\": 6", "\"offset\": 7");
        write_manifest(&dir, &bad);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn params_blob_size_is_checked() {
        let dir = std::env::temp_dir().join("vgc_manifest_params");
        write_manifest(&dir, &fake_manifest_json());
        std::fs::write(dir.join("m.params.bin"), vec![0u8; 12]).unwrap();
        let man = Manifest::load(&dir).unwrap();
        let m = man.model("m").unwrap().clone();
        assert!(man.load_params(&m).is_err());
        std::fs::write(dir.join("m.params.bin"), vec![0u8; 40]).unwrap();
        let p = man.load_params(&m).unwrap();
        assert_eq!(p.len(), 10);
    }
}
