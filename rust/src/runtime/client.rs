//! PJRT client wrapper: load AOT HLO-text artifacts and execute them.
//!
//! The pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. All
//! artifacts were lowered with `return_tuple=True`, so every execution
//! returns one tuple literal which we decompose into per-output
//! literals.

use std::path::Path;

use anyhow::{Context, Result};

// The offline build resolves `xla::` to the in-crate stand-in; to link
// the real PJRT bindings instead, point this alias back at the crate.
use super::xla;

/// Process-wide PJRT CPU client. Compiling an executable is expensive
/// (seconds for the grad graphs), so executables are cached by the
/// higher layers; the client itself is cheap to share.
pub struct Client {
    inner: xla::PjRtClient,
}

impl Client {
    pub fn cpu() -> Result<Client> {
        let inner = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Client { inner })
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable {
            inner: exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled artifact. One per (model, geometry) variant.
pub struct Executable {
    inner: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given input literals; returns the decomposed
    /// output tuple (all artifacts are lowered with `return_tuple=True`).
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .inner
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        tuple
            .to_tuple()
            .with_context(|| format!("decomposing result tuple of {}", self.name))
    }
}

// ---- host <-> literal marshalling ----

/// Build an f32 literal of the given dims from a flat host slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal_f32: {} elements for dims {dims:?}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given dims from a flat host slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal_i32: {} elements for dims {dims:?}",
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Copy a literal out to a host f32 vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_mismatch_is_error() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
        assert!(literal_f32(&[1.0; 4], &[2, 2]).is_ok());
    }

    #[test]
    fn literal_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 5.0, -6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_literal_roundtrip() {
        let data = vec![1i32, -2, 3, 4];
        let lit = literal_i32(&data, &[4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }
}
