//! Runtime bridge: load AOT HLO-text artifacts (built by `make
//! artifacts`) and execute them on the PJRT CPU client from the L3 hot
//! path. Python never runs at request time.

pub mod client;
pub mod manifest;
pub mod model;
pub mod xla;

pub use client::{literal_f32, literal_i32, to_vec_f32, Client, Executable};
pub use manifest::{Dtype, EvalKind, Group, Manifest, ModelEntry};
pub use model::{EvalOutput, ModelRuntime, StepMoments};
