//! Offline stand-in for the `xla` (PJRT bindings) crate.
//!
//! The runtime layer was written against the xla-rs API
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`), but the offline crate set this repo builds against does
//! not ship those bindings (DESIGN.md §Substitutions). This module
//! provides the same surface so the crate always compiles:
//!
//! * [`Literal`] is fully functional (host-side tensors: `vec1`,
//!   `reshape`, `to_vec`, tuples) — everything that does not need a
//!   real backend works, including the marshalling tests.
//! * Client/executable entry points return a descriptive [`XlaError`]
//!   at runtime. Code paths that need real execution first check for
//!   built artifacts and skip loudly when absent, so nothing in the
//!   tier-1 test suite depends on a live PJRT backend.
//!
//! Swapping in the real bindings is a one-line change in
//! `runtime/client.rs` (point the `xla` alias back at the crate).

use std::fmt;

/// Error type mirroring the binding crate's. Converts into
/// `anyhow::Error` through the std `Error` impl.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} requires the PJRT bindings, which are not part of the \
         offline build (see runtime/xla.rs)"
    ))
}

/// Element types the runtime marshals. Sealed to the two the artifacts
/// use (f32 samples/params, i32 tokens/labels).
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LitData;
    fn unwrap(data: &LitData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LitData {
        LitData::F32(data)
    }
    fn unwrap(data: &LitData) -> Option<&[f32]> {
        match data {
            LitData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LitData {
        LitData::I32(data)
    }
    fn unwrap(data: &LitData) -> Option<&[i32]> {
        match data {
            LitData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Literal storage: flat element buffer or a tuple of literals.
#[derive(Debug, Clone)]
pub enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal (functional part of the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    data: LitData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::wrap(data.to_vec()),
        }
    }

    fn len(&self) -> usize {
        match &self.data {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
            LitData::Tuple(parts) => parts.len(),
        }
    }

    /// Reshape without moving data; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if matches!(self.data, LitData::Tuple(_)) {
            return Err(XlaError("cannot reshape a tuple literal".into()));
        }
        if want as usize != self.len() {
            return Err(XlaError(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy elements out to a host vec.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| XlaError("literal element type mismatch".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self.data {
            LitData::Tuple(parts) => Ok(parts),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(unavailable("parsing HLO text"))
    }
}

/// Computation handle built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(unavailable("creating a PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(unavailable("compiling an HLO computation"))
    }
}

/// Device-side buffer returned by `execute` (never constructed here).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(unavailable("fetching a device buffer"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(unavailable("executing a compiled artifact"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn literal_type_mismatch_is_error() {
        let lit = Literal::vec1(&[1i32, 2]);
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn non_tuple_decompose_is_error() {
        let lit = Literal::vec1(&[1.0f32]);
        assert!(lit.to_tuple().is_err());
    }

    #[test]
    fn backend_entry_points_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("PJRT"), "{err}");
    }
}
