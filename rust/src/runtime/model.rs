//! Model runtime: a manifest entry bound to its compiled executables.
//!
//! One `ModelRuntime` owns the grad-moments executable (the training hot
//! path) and, lazily, the eval executable. It hides all literal
//! marshalling: the coordinator deals in flat `&[f32]` / `&[i32]` host
//! buffers only.

use anyhow::{Context, Result};

use super::client::{literal_f32, literal_i32, to_vec_f32, Client, Executable};
use super::manifest::{Dtype, EvalKind, Manifest, ModelEntry};

/// Output of one multi-worker grad-moments step.
///
/// Row-major `[P, N]` layouts; `gsum[w]` is worker w's Algorithm-1 `r`
/// increment (Σ_z ∇f_z / B) and `gsumsq[w]` its `v` increment
/// (Σ_z (∇f_z / B)²).
#[derive(Debug, Clone)]
pub struct StepMoments {
    pub loss: Vec<f32>,
    pub gsum: Vec<f32>,
    pub gsumsq: Vec<f32>,
    pub n: usize,
    pub workers: usize,
}

impl StepMoments {
    pub fn gsum_of(&self, worker: usize) -> &[f32] {
        &self.gsum[worker * self.n..(worker + 1) * self.n]
    }

    pub fn gsumsq_of(&self, worker: usize) -> &[f32] {
        &self.gsumsq[worker * self.n..(worker + 1) * self.n]
    }

    pub fn mean_loss(&self) -> f32 {
        crate::util::mean(&self.loss)
    }
}

/// Result of an eval call.
#[derive(Debug, Clone)]
pub enum EvalOutput {
    /// `[eval_batch * n_classes]` row-major logits.
    Logits(Vec<f32>),
    /// Scalar mean loss.
    Loss(f32),
}

pub struct ModelRuntime<'c> {
    client: &'c Client,
    pub entry: ModelEntry,
    manifest_dir: std::path::PathBuf,
    grad_exe: Executable,
    eval_exe: std::cell::OnceCell<Executable>,
}

impl<'c> ModelRuntime<'c> {
    /// Compile the grad executable for `model` (eval compiles lazily).
    pub fn load(client: &'c Client, manifest: &Manifest, model: &str) -> Result<Self> {
        let entry = manifest.model(model)?.clone();
        let grad_exe = client
            .load_hlo(manifest.path_of(&entry.grad_hlo))
            .with_context(|| format!("loading grad artifact for {model}"))?;
        Ok(ModelRuntime {
            client,
            entry,
            manifest_dir: manifest.dir.clone(),
            grad_exe,
            eval_exe: std::cell::OnceCell::new(),
        })
    }

    pub fn n_params(&self) -> usize {
        self.entry.n_params
    }

    pub fn workers(&self) -> usize {
        self.entry.workers
    }

    /// Execute one synchronous step's compute half.
    ///
    /// * `params` — flat parameter vector, length N.
    /// * `xs` — per-worker input batches, flattened `[P, B, *sample]`.
    ///   For f32 models pass `xs_f32`; for int32 (LM tokens) `xs_i32`.
    /// * `ys` — labels `[P, B]` (ignored by LMs but always supplied; the
    ///   lowered graph's signature includes them).
    pub fn step(
        &self,
        params: &[f32],
        xs_f32: Option<&[f32]>,
        xs_i32: Option<&[i32]>,
        ys: &[i32],
    ) -> Result<StepMoments> {
        let e = &self.entry;
        anyhow::ensure!(params.len() == e.n_params, "params length mismatch");
        let xs_dims = e.xs_dims();
        let xs_lit = match e.sample_dtype {
            Dtype::F32 => {
                let xs = xs_f32.ok_or_else(|| anyhow::anyhow!("model expects f32 inputs"))?;
                literal_f32(xs, &xs_dims)?
            }
            Dtype::I32 => {
                let xs = xs_i32.ok_or_else(|| anyhow::anyhow!("model expects i32 inputs"))?;
                literal_i32(xs, &xs_dims)?
            }
        };
        let p_lit = literal_f32(params, &[e.n_params as i64])?;
        let ys_lit = literal_i32(ys, &[e.workers as i64, e.batch as i64])?;

        let outs = self.grad_exe.execute(&[p_lit, xs_lit, ys_lit])?;
        anyhow::ensure!(outs.len() == 3, "grad artifact returned {} outputs", outs.len());
        let loss = to_vec_f32(&outs[0])?;
        let gsum = to_vec_f32(&outs[1])?;
        let gsumsq = to_vec_f32(&outs[2])?;
        anyhow::ensure!(loss.len() == e.workers, "loss shape mismatch");
        anyhow::ensure!(gsum.len() == e.workers * e.n_params, "gsum shape mismatch");
        anyhow::ensure!(
            gsumsq.len() == e.workers * e.n_params,
            "gsumsq shape mismatch"
        );
        Ok(StepMoments {
            loss,
            gsum,
            gsumsq,
            n: e.n_params,
            workers: e.workers,
        })
    }

    fn eval_exe(&self) -> Result<&Executable> {
        if self.eval_exe.get().is_none() {
            let exe = self
                .client
                .load_hlo(self.manifest_dir.join(&self.entry.eval_hlo))
                .with_context(|| format!("loading eval artifact for {}", self.entry.name))?;
            let _ = self.eval_exe.set(exe);
        }
        Ok(self.eval_exe.get().unwrap())
    }

    /// Run the eval artifact on one eval batch (`[eval_batch, *sample]`).
    pub fn eval(
        &self,
        params: &[f32],
        x_f32: Option<&[f32]>,
        x_i32: Option<&[i32]>,
    ) -> Result<EvalOutput> {
        let e = &self.entry;
        let mut dims = vec![e.eval_batch as i64];
        dims.extend(e.sample_shape.iter().map(|&d| d as i64));
        let x_lit = match e.sample_dtype {
            Dtype::F32 => literal_f32(
                x_f32.ok_or_else(|| anyhow::anyhow!("model expects f32 inputs"))?,
                &dims,
            )?,
            Dtype::I32 => literal_i32(
                x_i32.ok_or_else(|| anyhow::anyhow!("model expects i32 inputs"))?,
                &dims,
            )?,
        };
        let p_lit = literal_f32(params, &[e.n_params as i64])?;
        let outs = self.eval_exe()?.execute(&[p_lit, x_lit])?;
        match e.eval_kind {
            EvalKind::Logits => Ok(EvalOutput::Logits(to_vec_f32(&outs[0])?)),
            EvalKind::Loss => {
                let v = to_vec_f32(&outs[0])?;
                Ok(EvalOutput::Loss(v[0]))
            }
        }
    }

    /// Classification accuracy of logits against labels.
    pub fn accuracy(logits: &[f32], labels: &[i32], n_classes: usize) -> f32 {
        let n = labels.len();
        if n == 0 {
            return 0.0;
        }
        let mut correct = 0usize;
        for (i, &y) in labels.iter().enumerate() {
            let row = &logits[i * n_classes..(i + 1) * n_classes];
            let mut best = 0usize;
            for (k, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = k;
                }
            }
            if best as i32 == y {
                correct += 1;
            }
        }
        correct as f32 / n as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax_matches() {
        // 3 samples, 2 classes.
        let logits = vec![0.1, 0.9, 0.8, 0.2, 0.4, 0.6];
        let labels = vec![1, 0, 0];
        let acc = ModelRuntime::accuracy(&logits, &labels, 2);
        assert!((acc - 2.0 / 3.0).abs() < 1e-6);
        assert_eq!(ModelRuntime::accuracy(&[], &[], 2), 0.0);
    }

    #[test]
    fn step_moments_row_access() {
        let m = StepMoments {
            loss: vec![1.0, 3.0],
            gsum: vec![1.0, 2.0, 3.0, 4.0],
            gsumsq: vec![5.0, 6.0, 7.0, 8.0],
            n: 2,
            workers: 2,
        };
        assert_eq!(m.gsum_of(1), &[3.0, 4.0]);
        assert_eq!(m.gsumsq_of(0), &[5.0, 6.0]);
        assert_eq!(m.mean_loss(), 2.0);
    }
}
