//! Cross-layer properties of the closed-loop adaptive compression
//! stack (ISSUE 9):
//!
//! - with `--adaptive` off the Tunable surface must be invisible: for
//!   every one of the 9 codec specs, a codec whose knob is queried and
//!   re-applied at tightness u = 0 produces wire bytes bit-identical
//!   to one that never heard of knobs (the pre-adaptive static path);
//! - the controller is a pure function of (seed, telemetry): replaying
//!   a telemetry trace captured on a real fabric — ring or
//!   oversubscribed hierarchy — through independently constructed
//!   controllers yields identical knob decisions.

use vgc::comm::allgatherv::allgatherv_overlapped;
use vgc::comm::pipeline;
use vgc::compress::{Codec, CodecSpec, ControllerConfig, EncodeStats, KnobController, KnobUpdate};
use vgc::fabric::{FabricConfig, LinkSpec, TopologyKind};
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::rng::Pcg32;

/// Every spec the parser accepts — the full codec family.
fn all_nine_specs() -> Vec<CodecSpec> {
    vec![
        CodecSpec::None,
        CodecSpec::Vgc {
            alpha: 1.5,
            zeta: 0.95,
        },
        CodecSpec::VgcCompact {
            alpha: 1.5,
            zeta: 0.95,
        },
        CodecSpec::Strom { tau: 0.01 },
        CodecSpec::Hybrid {
            tau: 0.01,
            alpha: 1.5,
            zeta: 0.95,
        },
        CodecSpec::Qsgd {
            bits: 3,
            bucket: 256,
        },
        CodecSpec::TernGrad,
        CodecSpec::OneBit,
        CodecSpec::Adaptive { pi: 0.01 },
    ]
}

/// The overlap scheduler may fuse adjacent buckets, so the telemetry's
/// per-bucket comm vector can be shorter than the static bucket list;
/// redistribute the total by dense-byte weight (the trainer's
/// `align_bucket_comm`).
fn align_comm(comm: &[u64], weights: &[u64]) -> Vec<u64> {
    if comm.len() == weights.len() {
        return comm.to_vec();
    }
    let total: u128 = comm.iter().map(|&c| c as u128).sum();
    let wsum: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
    weights
        .iter()
        .map(|&w| (total * w as u128 / wsum) as u64)
        .collect()
}

#[test]
fn adaptive_off_is_bit_identical_to_static_for_all_nine_codec_specs() {
    let n = 2048;
    let workers = 3u64;
    let steps = 5;
    let layout = Layout::uniform(n, 256);
    for spec in all_nine_specs() {
        for w in 0..workers {
            // `plain` never touches the Tunable surface; `idle` is
            // driven the way an adaptive run at rest drives it — knob
            // read every step and re-applied at its current value
            // (tightness u = 0). Residual/variance state evolves across
            // steps, so equality here covers the stateful path too.
            let seed = 7u64.wrapping_add(w);
            let mut plain = spec.build(&layout, seed);
            let mut idle = spec.build(&layout, seed);
            let mut rng = Pcg32::new(0x5EED_1D ^ 9, w);
            for step in 0..steps {
                let g = testkit::gradient_vec(&mut rng, n);
                let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
                match idle.knob() {
                    Some(k) => {
                        // u = 0 must map exactly onto the current value.
                        assert_eq!(
                            k.at_tightness(k.value, 0.0),
                            k.value,
                            "{spec:?}: tightness 0 must be the static point"
                        );
                        if !idle.set_knob_range(0, n, k.value) {
                            assert!(
                                idle.set_knob(k.value),
                                "{spec:?}: tunable codec rejected its own knob value"
                            );
                        }
                    }
                    None => {
                        assert!(
                            !idle.set_knob(0.5),
                            "{spec:?}: non-tunable codec must reject set_knob"
                        );
                        assert!(!idle.set_knob_range(0, n, 0.5));
                    }
                }
                let a = plain.encode_step(&g, &sq);
                let b = idle.encode_step(&g, &sq);
                assert_eq!(
                    a.bytes, b.bytes,
                    "{spec:?} w={w} step={step}: wire bytes diverged"
                );
                assert_eq!(a.elements, b.elements, "{spec:?} w={w} step={step}");
                assert_eq!(a.payload_bits, b.payload_bits, "{spec:?} w={w} step={step}");
            }
        }
    }
}

#[test]
fn controller_replay_is_deterministic_across_topologies() {
    let n = 8192;
    let p = 4usize;
    let steps = 6;
    let layout = Layout::uniform(n, 256);
    let buckets = pipeline::form_buckets(&layout, 4096);
    let weights = pipeline::bucket_weights(&buckets);
    let ranges: Vec<(usize, usize)> = buckets
        .iter()
        .map(|b| (b.params.start, b.params.end))
        .collect();
    let spec = CodecSpec::Vgc {
        alpha: 0.5,
        zeta: 0.95,
    };
    for kind in [TopologyKind::Ring, TopologyKind::Hier { groups: 2 }] {
        let cfg = FabricConfig {
            topology: kind,
            link: LinkSpec {
                bandwidth_gbps: 0.05,
                latency_us: 10.0,
                jitter_us: 0.0,
            },
            inter_rack_gbps: match kind {
                TopologyKind::Hier { .. } => Some(0.02),
                _ => None,
            },
            seed: 1,
            ..FabricConfig::default()
        };

        // Capture a real telemetry trace: encode on every worker,
        // gather over the fabric, record what the trainer would feed
        // the controller each step.
        let mut codecs: Vec<Box<dyn Codec>> =
            (0..p).map(|w| spec.build(&layout, w as u64)).collect();
        let knob = codecs[0].knob().expect("vgc is tunable");
        let mut rngs: Vec<Pcg32> = (0..p).map(|w| Pcg32::new(0xFAB ^ 3, w as u64)).collect();
        let cpu_ps = 1_000_000u64; // 1 µs: comm-dominated on this slow fabric
        let mut trace: Vec<(Vec<u64>, f64, f64)> = Vec::new();
        for _ in 0..steps {
            let mut elements = 0u64;
            let mut payload_bits = 0u64;
            let msgs: Vec<Vec<u8>> = codecs
                .iter_mut()
                .zip(rngs.iter_mut())
                .map(|(c, r)| {
                    let g = testkit::gradient_vec(r, n);
                    let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
                    let m = c.encode_step(&g, &sq);
                    elements += m.elements;
                    payload_bits += m.payload_bits;
                    m.bytes
                })
                .collect();
            let ov = allgatherv_overlapped(&cfg, &msgs, &weights, cpu_ps, 0);
            let stats = EncodeStats {
                elements,
                payload_bits,
            };
            trace.push((
                align_comm(&ov.telemetry.bucket_comm_ps, &weights),
                ov.telemetry.uplink_byte_fraction(),
                stats.gain(n * p),
            ));
        }

        // Replay: two controllers built independently from the same
        // (config, knob, buckets) must make identical decisions on the
        // trace — construction order and wall clock play no part.
        let mk = || {
            KnobController::new(
                ControllerConfig {
                    target: 0.5,
                    seed: 42,
                    ..ControllerConfig::default()
                },
                knob,
                ranges.clone(),
            )
        };
        let (mut a, mut b) = (mk(), mk());
        let ua: Vec<Vec<KnobUpdate>> = trace
            .iter()
            .map(|(comm, uplink, gain)| a.observe(comm, cpu_ps, *uplink, *gain))
            .collect();
        let ub: Vec<Vec<KnobUpdate>> = trace
            .iter()
            .map(|(comm, uplink, gain)| b.observe(comm, cpu_ps, *uplink, *gain))
            .collect();
        assert_eq!(ua, ub, "{kind:?}: replay diverged");
        let last = &trace.last().unwrap().0;
        assert_eq!(
            a.scalar_value(last).to_bits(),
            b.scalar_value(last).to_bits(),
            "{kind:?}: scalar collapse diverged"
        );
        // The comm-bound trace must actually exercise the control law
        // (an all-empty replay would prove nothing).
        assert!(
            ua.iter().any(|u| !u.is_empty()),
            "{kind:?}: trace never moved the knob"
        );
    }
}
