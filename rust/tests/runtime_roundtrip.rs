//! Integration: load real AOT artifacts and execute them end-to-end.
//!
//! Requires `make artifacts` to have run (skips loudly otherwise).

use vgc::runtime::{Client, EvalOutput, Manifest, ModelRuntime};
use vgc::util::rng::Pcg32;

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

#[test]
fn mlp_grad_step_executes_and_is_sane() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &man, "mlp").unwrap();
    let e = rt.entry.clone();
    let params = man.load_params(&e).unwrap();

    let mut rng = Pcg32::new(0, 0);
    let xs: Vec<f32> = (0..e.workers * e.batch * e.sample_elems())
        .map(|_| rng.next_normal())
        .collect();
    let ys: Vec<i32> = (0..e.workers * e.batch)
        .map(|_| rng.next_bounded(e.n_classes as u32) as i32)
        .collect();

    let out = rt.step(&params, Some(&xs), None, &ys).unwrap();
    assert_eq!(out.loss.len(), e.workers);
    assert_eq!(out.gsum.len(), e.workers * e.n_params);
    // Fresh random data, 10 classes: loss must be near ln(10).
    for &l in &out.loss {
        assert!(l.is_finite() && l > 1.0 && l < 5.0, "loss={l}");
    }
    // v increments are sums of squares: non-negative everywhere.
    assert!(out.gsumsq.iter().all(|&v| v >= 0.0));
    // Workers see different shards => different moments.
    assert_ne!(out.gsum_of(0), out.gsum_of(1));
}

#[test]
fn mlp_eval_returns_logits() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &man, "mlp").unwrap();
    let e = rt.entry.clone();
    let params = man.load_params(&e).unwrap();
    let x = vec![0.5f32; e.eval_batch * e.sample_elems()];
    match rt.eval(&params, Some(&x), None).unwrap() {
        EvalOutput::Logits(logits) => {
            assert_eq!(logits.len(), e.eval_batch * e.n_classes);
            assert!(logits.iter().all(|v| v.is_finite()));
        }
        other => panic!("expected logits, got {other:?}"),
    }
}

#[test]
fn step_rejects_wrong_shapes() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &man, "mlp").unwrap();
    let e = rt.entry.clone();
    let params = man.load_params(&e).unwrap();
    let good_xs = vec![0.0f32; e.workers * e.batch * e.sample_elems()];
    let good_ys = vec![0i32; e.workers * e.batch];

    // Wrong params length.
    assert!(rt.step(&params[..10], Some(&good_xs), None, &good_ys).is_err());
    // Wrong xs length.
    assert!(rt.step(&params, Some(&good_xs[..8]), None, &good_ys).is_err());
    // Wrong dtype: model expects f32 inputs, i32 supplied.
    let bad_i32 = vec![0i32; good_xs.len()];
    assert!(rt.step(&params, None, Some(&bad_i32), &good_ys).is_err());
}

#[test]
fn grad_matches_across_repeated_execution() {
    // PJRT execution must be deterministic: same inputs, same moments.
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &man, "mlp").unwrap();
    let e = rt.entry.clone();
    let params = man.load_params(&e).unwrap();
    let mut rng = Pcg32::new(1, 1);
    let xs: Vec<f32> = (0..e.workers * e.batch * e.sample_elems())
        .map(|_| rng.next_normal())
        .collect();
    let ys: Vec<i32> = (0..e.workers * e.batch)
        .map(|_| rng.next_bounded(e.n_classes as u32) as i32)
        .collect();
    let a = rt.step(&params, Some(&xs), None, &ys).unwrap();
    let b = rt.step(&params, Some(&xs), None, &ys).unwrap();
    assert_eq!(a.gsum, b.gsum);
    assert_eq!(a.gsumsq, b.gsumsq);
    assert_eq!(a.loss, b.loss);
}

#[test]
fn gsumsq_consistent_with_gsum_scale() {
    // Cauchy-Schwarz over the batch: (Σ g/B)² ≤ B · Σ (g/B)², i.e.
    // gsum² ≤ B · gsumsq elementwise — a cheap cross-check that the two
    // outputs really are the first and second moments of one stream.
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let rt = ModelRuntime::load(&client, &man, "mlp").unwrap();
    let e = rt.entry.clone();
    let params = man.load_params(&e).unwrap();
    let mut rng = Pcg32::new(2, 2);
    let xs: Vec<f32> = (0..e.workers * e.batch * e.sample_elems())
        .map(|_| rng.next_normal())
        .collect();
    let ys: Vec<i32> = (0..e.workers * e.batch)
        .map(|_| rng.next_bounded(e.n_classes as u32) as i32)
        .collect();
    let out = rt.step(&params, Some(&xs), None, &ys).unwrap();
    let b = e.batch as f32;
    for w in 0..e.workers {
        let gs = out.gsum_of(w);
        let gss = out.gsumsq_of(w);
        for i in 0..e.n_params {
            assert!(
                gs[i] * gs[i] <= b * gss[i] + 1e-6,
                "w={w} i={i}: {} vs {}",
                gs[i] * gs[i],
                b * gss[i]
            );
        }
    }
}
