//! Fabric integration properties (no XLA dependency — run everywhere):
//!
//! * simulated ring-allgatherv traffic equals the analytic cost
//!   model's byte counts for random worker counts / message sizes;
//! * every topology delivers complete, uncorrupted gathers and exact
//!   sums;
//! * two same-seed runs produce identical event traces (determinism
//!   under jitter + stragglers);
//! * stragglers strictly slow completion;
//! * the simulated ring respects the paper's analytic `T_v` bound for
//!   uniform messages.

use vgc::comm::allgatherv::ring_allgatherv;
use vgc::comm::costmodel::{ring_gatherv_bytes_per_node, CostModel, LinkModel};
use vgc::fabric::{
    build_topology, Fabric, FabricConfig, LinkSpec, Straggler, TopologyKind, TraceEvent,
};
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn all_kinds() -> Vec<TopologyKind> {
    vec![
        TopologyKind::Ring,
        TopologyKind::Full,
        TopologyKind::Star,
        TopologyKind::Tree { branch: 3 },
        TopologyKind::Tree { branch: 1 },
    ]
}

fn rand_messages(rng: &mut Pcg32, p: usize, max_len: usize) -> Vec<Vec<u8>> {
    (0..p)
        .map(|_| {
            let len = testkit::usize_in(rng, 0, max_len);
            (0..len).map(|_| rng.next_u32() as u8).collect()
        })
        .collect()
}

#[test]
fn ring_traffic_equals_analytic_byte_counts() {
    testkit::for_all(
        "ring gatherv bytes == analytic",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 1, 12);
            rand_messages(rng, p, 300)
        },
        |inputs| {
            let sizes: Vec<u64> = inputs.iter().map(|m| m.len() as u64).collect();
            let want = ring_gatherv_bytes_per_node(&sizes);
            // Through the fabric directly…
            let topo = build_topology(TopologyKind::Ring, inputs.len());
            let mut fabric =
                Fabric::for_config(&FabricConfig::default(), topo.node_count());
            let sim = topo.allgatherv(&mut fabric, inputs);
            if sim.traffic.bytes_sent_per_node != want {
                return Err(format!(
                    "fabric {:?} != analytic {:?}",
                    sim.traffic.bytes_sent_per_node, want
                ));
            }
            // …and through the comm front (must agree with both).
            let front = ring_allgatherv(inputs);
            if front.traffic.bytes_sent_per_node != want {
                return Err("comm front diverged from analytic counts".into());
            }
            if front.traffic.rounds != inputs.len() as u32 - 1 {
                return Err(format!("rounds {}", front.traffic.rounds));
            }
            Ok(())
        },
    );
}

#[test]
fn every_topology_gathers_completely() {
    testkit::for_all(
        "topology gather completeness",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 1, 9);
            rand_messages(rng, p, 64)
        },
        |inputs| {
            let p = inputs.len();
            for kind in all_kinds() {
                let topo = build_topology(kind, p);
                let mut fabric =
                    Fabric::for_config(&FabricConfig::default(), topo.node_count());
                let sim = topo.allgatherv(&mut fabric, inputs);
                for dst in 0..p {
                    for src in 0..p {
                        if sim.gathered[dst][src] != inputs[src] {
                            return Err(format!(
                                "{}: corrupt at dst={dst} src={src}",
                                kind.label()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn every_topology_allreduces_to_the_sum() {
    testkit::for_all(
        "topology allreduce == sum",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 1, 8);
            let n = testkit::usize_in(rng, 1, 97);
            (0..p)
                .map(|_| testkit::gradient_vec(rng, n))
                .collect::<Vec<_>>()
        },
        |inputs| {
            let p = inputs.len();
            let n = inputs[0].len();
            for kind in all_kinds() {
                let topo = build_topology(kind, p);
                let mut fabric =
                    Fabric::for_config(&FabricConfig::default(), topo.node_count());
                let sim = topo.allreduce(&mut fabric, inputs);
                for i in 0..n {
                    let want: f64 = inputs.iter().map(|v| v[i] as f64).sum();
                    for node in 0..p {
                        let got = sim.reduced[node][i] as f64;
                        if (got - want).abs() > 1e-4 * (1.0 + want.abs()) {
                            return Err(format!(
                                "{}: node {node} i={i}: {got} != {want}",
                                kind.label()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

fn noisy_config(seed: u64) -> FabricConfig {
    FabricConfig {
        topology: TopologyKind::Ring,
        link: LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 20.0,
            jitter_us: 15.0,
        },
        seed,
        stragglers: vec![
            Straggler {
                node: 1,
                slowdown: 2.5,
            },
            Straggler {
                node: 4,
                slowdown: 1.5,
            },
        ],
    }
}

fn run_once(cfg: &FabricConfig, p: usize) -> (Vec<TraceEvent>, u64) {
    let inputs: Vec<Vec<u8>> = (0..p).map(|w| vec![w as u8; 500 + w * 97]).collect();
    let topo = build_topology(cfg.topology, p);
    let mut fabric = Fabric::for_config(cfg, topo.node_count());
    let sim = topo.allgatherv(&mut fabric, &inputs);
    (fabric.trace().to_vec(), sim.time_ps)
}

#[test]
fn same_seed_runs_replay_identical_traces() {
    let cfg = noisy_config(42);
    let (trace_a, time_a) = run_once(&cfg, 6);
    let (trace_b, time_b) = run_once(&cfg, 6);
    assert!(!trace_a.is_empty());
    assert_eq!(trace_a, trace_b, "same-seed traces diverged");
    assert_eq!(time_a, time_b);
}

#[test]
fn different_jitter_seeds_diverge() {
    let (trace_a, _) = run_once(&noisy_config(42), 6);
    let (trace_b, _) = run_once(&noisy_config(43), 6);
    assert_ne!(trace_a, trace_b, "jitter ignored the seed");
}

#[test]
fn stragglers_strictly_slow_every_topology() {
    let p = 6;
    let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![7u8; 10_000]).collect();
    for kind in all_kinds() {
        let base = FabricConfig {
            topology: kind,
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 10.0,
                jitter_us: 0.0,
            },
            seed: 0,
            stragglers: Vec::new(),
        };
        let topo = build_topology(kind, p);
        let mut healthy = Fabric::for_config(&base, topo.node_count());
        let t0 = topo.allgatherv(&mut healthy, &inputs).time_ps;
        let slowed_cfg = FabricConfig {
            stragglers: vec![Straggler {
                node: 2,
                slowdown: 8.0,
            }],
            ..base
        };
        let mut slowed = Fabric::for_config(&slowed_cfg, topo.node_count());
        let t1 = topo.allgatherv(&mut slowed, &inputs).time_ps;
        assert!(
            t1 > t0,
            "{}: straggler did not slow the collective ({t0} vs {t1})",
            kind.label()
        );
    }
}

#[test]
fn simulated_ring_within_analytic_bound_for_uniform_messages() {
    for p in [2usize, 3, 4, 8, 16] {
        for bytes in [1_000u64, 50_000, 1_000_000] {
            let model = CostModel::new(p, 1_000_000, LinkModel::gige());
            let check = model.crosscheck_ring_gatherv(&vec![bytes; p]);
            assert!(
                check.within_bound(),
                "p={p} bytes={bytes}: sim {} s > bound {} s",
                check.simulated_s,
                check.analytic_s
            );
        }
    }
}

#[test]
fn comm_front_and_fabric_ring_agree_bit_for_bit() {
    let mut rng = Pcg32::new(7, 1);
    let inputs = rand_messages(&mut rng, 5, 200);
    let front = ring_allgatherv(&inputs);
    let topo = build_topology(TopologyKind::Ring, 5);
    let mut fabric = Fabric::for_config(&FabricConfig::default(), topo.node_count());
    let sim = topo.allgatherv(&mut fabric, &inputs);
    assert_eq!(front.gathered, sim.gathered);
    assert_eq!(front.traffic, sim.traffic);
}
