//! Fabric integration properties (no XLA dependency — run everywhere):
//!
//! * simulated allgatherv traffic equals the analytic cost model's
//!   byte counts (ring, torus, hierarchy) for random worker counts /
//!   message sizes;
//! * every topology delivers complete, uncorrupted gathers and exact
//!   sums — segmented or not, under jitter-reordered segments;
//! * two same-seed runs produce identical event traces (determinism
//!   under jitter + stragglers, all topologies);
//! * stragglers strictly slow completion;
//! * segmentation monotonically speeds a skewed ring gather as the
//!   segment shrinks toward the cost model's block size `m`, and the
//!   segmented time lands within 5% of the analytic pipelined `T_v`
//!   bound where whole-message forwarding overshoots it;
//! * star/tree/hier completion times fall inside the closed-form
//!   port-work brackets (`costmodel::star_gather_time_bounds` et al.)
//!   for random sizes, branches, group counts, and uplink rates;
//! * the trainer-facing `comm::allgatherv` front honors the configured
//!   topology (same bytes, topology-shaped timing);
//! * the analytic-vs-sim crosscheck holds at scale: 1024- and
//!   2048-node ring/torus/hier gathers (phantom payloads) match the
//!   closed-form byte counts exactly, the ring lands inside an
//!   asserted fraction of the cost model's `T_v`, and the hierarchy
//!   stays inside its port-work bracket;
//! * `SimClock` tie-breaking is deterministic at 10⁵⁺ pending events —
//!   the lane queues, the overflow heap, and any mix of the two pop
//!   the same (time, insertion-order) stream;
//! * a 1024-node hierarchy survives a crashed node through
//!   `allgatherv_faulty` (route-around, masked bit-identity) and runs
//!   `allgatherv_overlapped` with overlapped ≤ phased.

use vgc::comm::allgatherv::{allgatherv, allgatherv_faulty, allgatherv_overlapped, ring_allgatherv};
use vgc::comm::costmodel::{
    hier_gather_time_bounds, hier_gatherv_bytes_per_node, ring_gatherv_bytes_per_node,
    star_gather_time_bounds, torus_gatherv_bytes_per_node, tree_gather_time_bounds, CostModel,
    LinkModel,
};
use vgc::fabric::hierarchy::group_spans;
use vgc::fabric::{
    build_topology, gather_sized, Engine, Fabric, FabricConfig, LinkSpec, SimClock, Straggler,
    TopologyKind, TraceEvent,
};
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn all_kinds() -> Vec<TopologyKind> {
    vec![
        TopologyKind::Ring,
        TopologyKind::Full,
        TopologyKind::Star,
        TopologyKind::Tree { branch: 3 },
        TopologyKind::Tree { branch: 1 },
        TopologyKind::Torus { rows: 0, cols: 0 },
        TopologyKind::Hier { groups: 0 },
        TopologyKind::Hier { groups: 2 },
    ]
}

fn rand_messages(rng: &mut Pcg32, p: usize, max_len: usize) -> Vec<Vec<u8>> {
    (0..p)
        .map(|_| {
            let len = testkit::usize_in(rng, 0, max_len);
            (0..len).map(|_| rng.next_u32() as u8).collect()
        })
        .collect()
}

#[test]
fn ring_traffic_equals_analytic_byte_counts() {
    testkit::for_all(
        "ring gatherv bytes == analytic",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 1, 12);
            rand_messages(rng, p, 300)
        },
        |inputs| {
            let sizes: Vec<u64> = inputs.iter().map(|m| m.len() as u64).collect();
            let want = ring_gatherv_bytes_per_node(&sizes);
            // Through the fabric directly…
            let topo = build_topology(TopologyKind::Ring, inputs.len());
            let mut fabric =
                Fabric::for_config(&FabricConfig::default(), topo.node_count());
            let sim = topo.allgatherv(&mut fabric, inputs);
            if sim.traffic.bytes_sent_per_node != want {
                return Err(format!(
                    "fabric {:?} != analytic {:?}",
                    sim.traffic.bytes_sent_per_node, want
                ));
            }
            // …and through the comm front (must agree with both).
            let front = ring_allgatherv(inputs);
            if front.traffic.bytes_sent_per_node != want {
                return Err("comm front diverged from analytic counts".into());
            }
            if front.traffic.rounds != inputs.len() as u32 - 1 {
                return Err(format!("rounds {}", front.traffic.rounds));
            }
            Ok(())
        },
    );
}

#[test]
fn torus_and_hier_traffic_equal_analytic_byte_counts() {
    testkit::for_all(
        "torus/hier gatherv bytes == analytic",
        |rng: &mut Pcg32| {
            let rows = testkit::usize_in(rng, 1, 4);
            let cols = testkit::usize_in(rng, 1, 4);
            let groups = testkit::usize_in(rng, 1, rows * cols);
            let msgs = rand_messages(rng, rows * cols, 200);
            (rows, cols, groups, msgs)
        },
        |(rows, cols, groups, inputs)| {
            let p = inputs.len();
            let sizes: Vec<u64> = inputs.iter().map(|m| m.len() as u64).collect();

            let kind = TopologyKind::Torus {
                rows: *rows,
                cols: *cols,
            };
            let topo = build_topology(kind, p);
            let mut fabric = Fabric::for_topology(&FabricConfig::default(), &*topo);
            let sim = topo.allgatherv(&mut fabric, inputs);
            let want = torus_gatherv_bytes_per_node(&sizes, *rows, *cols);
            if sim.traffic.bytes_sent_per_node != want {
                return Err(format!(
                    "torus {rows}x{cols}: fabric {:?} != analytic {:?}",
                    sim.traffic.bytes_sent_per_node, want
                ));
            }

            let kind = TopologyKind::Hier { groups: *groups };
            let topo = build_topology(kind, p);
            // Uplink overrides change timing, never byte counts.
            let mut fabric = Fabric::for_topology(&FabricConfig::default(), &*topo);
            let sim = topo.allgatherv(&mut fabric, inputs);
            let want = hier_gatherv_bytes_per_node(&sizes, &group_spans(p, *groups));
            if sim.traffic.bytes_sent_per_node != want {
                return Err(format!(
                    "hier g={groups}: fabric {:?} != analytic {:?}",
                    sim.traffic.bytes_sent_per_node, want
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn every_topology_gathers_completely() {
    testkit::for_all(
        "topology gather completeness",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 1, 9);
            rand_messages(rng, p, 64)
        },
        |inputs| {
            let p = inputs.len();
            for kind in all_kinds() {
                if kind.validate(p).is_err() {
                    continue; // e.g. hier:2 cannot host a single worker
                }
                let topo = build_topology(kind, p);
                let mut fabric =
                    Fabric::for_topology(&FabricConfig::default(), &*topo);
                let sim = topo.allgatherv(&mut fabric, inputs);
                for dst in 0..p {
                    for src in 0..p {
                        if sim.gathered[dst][src] != inputs[src] {
                            return Err(format!(
                                "{}: corrupt at dst={dst} src={src}",
                                kind.label()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn segmented_gathers_reassemble_under_jitter() {
    // Tiny segments + jitter force out-of-order segment deliveries;
    // every topology must still reassemble every message exactly.
    testkit::for_all(
        "segmented gather completeness",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 2, 8);
            (testkit::usize_in(rng, 0, 1000) as u64, rand_messages(rng, p, 96))
        },
        |(seed, inputs)| {
            let p = inputs.len();
            for kind in all_kinds() {
                let cfg = FabricConfig {
                    topology: kind,
                    link: LinkSpec {
                        bandwidth_gbps: 1.0,
                        latency_us: 5.0,
                        jitter_us: 20.0,
                    },
                    segment_bytes: 7,
                    seed: *seed,
                    ..FabricConfig::default()
                };
                let topo = build_topology(kind, p);
                let mut fabric = Fabric::for_topology(&cfg, &*topo);
                let sim = topo.allgatherv(&mut fabric, inputs);
                for dst in 0..p {
                    for src in 0..p {
                        if sim.gathered[dst][src] != inputs[src] {
                            return Err(format!(
                                "{}: corrupt at dst={dst} src={src}",
                                kind.label()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn every_topology_allreduces_to_the_sum() {
    testkit::for_all(
        "topology allreduce == sum",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 1, 8);
            let n = testkit::usize_in(rng, 1, 97);
            (0..p)
                .map(|_| testkit::gradient_vec(rng, n))
                .collect::<Vec<_>>()
        },
        |inputs| {
            let p = inputs.len();
            let n = inputs[0].len();
            for kind in all_kinds() {
                if kind.validate(p).is_err() {
                    continue;
                }
                let topo = build_topology(kind, p);
                let mut fabric =
                    Fabric::for_topology(&FabricConfig::default(), &*topo);
                let sim = topo.allreduce(&mut fabric, inputs);
                for i in 0..n {
                    let want: f64 = inputs.iter().map(|v| v[i] as f64).sum();
                    for node in 0..p {
                        let got = sim.reduced[node][i] as f64;
                        if (got - want).abs() > 1e-4 * (1.0 + want.abs()) {
                            return Err(format!(
                                "{}: node {node} i={i}: {got} != {want}",
                                kind.label()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

fn noisy_config(kind: TopologyKind, seed: u64) -> FabricConfig {
    FabricConfig {
        topology: kind,
        link: LinkSpec {
            bandwidth_gbps: 1.0,
            latency_us: 20.0,
            jitter_us: 15.0,
        },
        segment_bytes: 190,
        seed,
        stragglers: vec![
            Straggler {
                node: 1,
                slowdown: 2.5,
            },
            Straggler {
                node: 4,
                slowdown: 1.5,
            },
        ],
        ..FabricConfig::default()
    }
}

fn run_once(cfg: &FabricConfig, p: usize) -> (Vec<TraceEvent>, u64) {
    let inputs: Vec<Vec<u8>> = (0..p).map(|w| vec![w as u8; 500 + w * 97]).collect();
    let topo = build_topology(cfg.topology, p);
    let mut fabric = Fabric::for_topology(cfg, &*topo);
    let sim = topo.allgatherv(&mut fabric, &inputs);
    (fabric.trace().to_vec(), sim.time_ps)
}

#[test]
fn same_seed_runs_replay_identical_traces() {
    for kind in [
        TopologyKind::Ring,
        TopologyKind::Torus { rows: 2, cols: 3 },
        TopologyKind::Hier { groups: 2 },
    ] {
        let cfg = noisy_config(kind, 42);
        let (trace_a, time_a) = run_once(&cfg, 6);
        let (trace_b, time_b) = run_once(&cfg, 6);
        assert!(!trace_a.is_empty());
        assert_eq!(trace_a, trace_b, "{}: same-seed traces diverged", kind.label());
        assert_eq!(time_a, time_b);
    }
}

#[test]
fn different_jitter_seeds_diverge() {
    let (trace_a, _) = run_once(&noisy_config(TopologyKind::Ring, 42), 6);
    let (trace_b, _) = run_once(&noisy_config(TopologyKind::Ring, 43), 6);
    assert_ne!(trace_a, trace_b, "jitter ignored the seed");
}

#[test]
fn stragglers_strictly_slow_every_topology() {
    let p = 6;
    let inputs: Vec<Vec<u8>> = (0..p).map(|_| vec![7u8; 10_000]).collect();
    for kind in all_kinds() {
        let base = FabricConfig {
            topology: kind,
            link: LinkSpec {
                bandwidth_gbps: 1.0,
                latency_us: 10.0,
                jitter_us: 0.0,
            },
            seed: 0,
            ..FabricConfig::default()
        };
        let topo = build_topology(kind, p);
        let mut healthy = Fabric::for_topology(&base, &*topo);
        let t0 = topo.allgatherv(&mut healthy, &inputs).time_ps;
        let slowed_cfg = FabricConfig {
            stragglers: vec![Straggler {
                node: 2,
                slowdown: 8.0,
            }],
            ..base
        };
        let mut slowed = Fabric::for_topology(&slowed_cfg, &*topo);
        let t1 = topo.allgatherv(&mut slowed, &inputs).time_ps;
        assert!(
            t1 > t0,
            "{}: straggler did not slow the collective ({t0} vs {t1})",
            kind.label()
        );
    }
}

#[test]
fn simulated_ring_within_analytic_bound_for_uniform_messages() {
    for p in [2usize, 3, 4, 8, 16] {
        for bytes in [1_000u64, 50_000, 1_000_000] {
            let model = CostModel::new(p, 1_000_000, LinkModel::gige());
            let check = model.crosscheck_ring_gatherv(&vec![bytes; p]);
            assert!(
                check.within_bound(),
                "p={p} bytes={bytes}: sim {} s > bound {} s",
                check.simulated_s,
                check.analytic_s
            );
        }
    }
}

#[test]
fn star_tree_hier_times_fall_within_closed_form_brackets() {
    testkit::for_all(
        "gather time within closed-form port-work brackets",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 2, 10);
            let branch = testkit::usize_in(rng, 1, p);
            let groups = testkit::usize_in(rng, 1, p);
            let uplink_gbps = [0.1, 0.5, 1.0][testkit::usize_in(rng, 0, 2)];
            (branch, groups, uplink_gbps, rand_messages(rng, p, 4000))
        },
        |(branch, groups, uplink_gbps, inputs)| {
            let p = inputs.len();
            let sizes: Vec<u64> = inputs.iter().map(|m| m.len() as u64).collect();
            let base = FabricConfig::default(); // GigE, zero jitter, unsegmented
            let link = base.link.to_cost_model();
            let check = |label: &str, sim_s: f64, b: vgc::comm::costmodel::GatherTimeBound| {
                if b.brackets(sim_s) {
                    Ok(())
                } else {
                    Err(format!(
                        "{label}: simulated {sim_s} s outside [{}, {}] s",
                        b.lower_s, b.upper_s
                    ))
                }
            };

            let topo = build_topology(TopologyKind::Star, p);
            let mut fabric = Fabric::for_topology(&base, &*topo);
            let sim = topo.allgatherv(&mut fabric, inputs);
            check(
                &format!("star p={p}"),
                sim.time_secs(),
                star_gather_time_bounds(&link, &sizes),
            )?;

            let kind = TopologyKind::Tree { branch: *branch };
            let topo = build_topology(kind, p);
            let mut fabric = Fabric::for_topology(&base, &*topo);
            let sim = topo.allgatherv(&mut fabric, inputs);
            check(
                &format!("tree p={p} b={branch}"),
                sim.time_secs(),
                tree_gather_time_bounds(&link, &sizes, *branch),
            )?;

            let cfg = FabricConfig {
                topology: TopologyKind::Hier { groups: *groups },
                inter_rack_gbps: Some(*uplink_gbps),
                ..FabricConfig::default()
            };
            let topo = build_topology(cfg.topology, p);
            let mut fabric = Fabric::for_topology(&cfg, &*topo);
            let sim = topo.allgatherv(&mut fabric, inputs);
            let uplink = LinkModel {
                beta: 1e-9 / uplink_gbps,
                latency: link.latency,
            };
            check(
                &format!("hier p={p} g={groups} up={uplink_gbps}"),
                sim.time_secs(),
                hier_gather_time_bounds(&link, &uplink, &sizes, &group_spans(p, *groups)),
            )
        },
    );
}

#[test]
fn segmentation_monotonically_speeds_skewed_ring_gather() {
    // One dominant message; shrinking the segment toward the cost
    // model's 8 KiB block must never slow the gather (tiny tolerance
    // for per-segment serialization rounding).
    let sizes = [200_000usize, 500, 500, 500];
    let inputs: Vec<Vec<u8>> = sizes.iter().map(|&s| vec![1u8; s]).collect();
    let topo = build_topology(TopologyKind::Ring, 4);
    let mut last = u64::MAX;
    for seg in [0usize, 65_536, 16_384, 8_192] {
        let cfg = FabricConfig {
            segment_bytes: seg,
            ..FabricConfig::default()
        };
        let mut fabric = Fabric::for_topology(&cfg, &*topo);
        let t = topo.allgatherv(&mut fabric, &inputs).time_ps;
        assert!(
            t <= last.saturating_add(last / 1000),
            "segment {seg}: time {t} ps regressed over {last} ps"
        );
        last = t;
    }
}

#[test]
fn segmented_ring_converges_to_tv_bound_for_skewed_messages() {
    // One 1 MB message among 100 B peers. Whole-message forwarding
    // pays ~3 full serializations on the critical path and overshoots
    // the pipelined bound; segmenting at the model's block size m
    // lands within 5% of T_v — the acceptance regime of the paper's
    // Section 5 analysis for skewed per-node message sizes.
    let sizes = vec![1_000_000u64, 100, 100, 100];
    let model = CostModel::new(
        4,
        2_000_000,
        LinkModel {
            beta: 1e-9,
            latency: 5e-6,
        },
    );
    let seg = model.crosscheck_ring_gatherv_segmented(&sizes);
    assert!(seg.simulated_s > 0.0);
    let ratio = seg.simulated_s / seg.analytic_s;
    assert!(
        (0.95..=1.05).contains(&ratio),
        "segmented sim {} s vs bound {} s (ratio {ratio})",
        seg.simulated_s,
        seg.analytic_s
    );
    let whole = model.crosscheck_ring_gatherv(&sizes);
    assert!(
        whole.simulated_s > whole.analytic_s,
        "store-and-forward should overshoot the pipelined bound: {} vs {}",
        whole.simulated_s,
        whole.analytic_s
    );
}

#[test]
fn comm_front_honors_configured_topology() {
    let mut rng = Pcg32::new(11, 2);
    let inputs = rand_messages(&mut rng, 6, 128);
    let ring = ring_allgatherv(&inputs);
    for kind in [
        TopologyKind::Star,
        TopologyKind::Torus { rows: 2, cols: 3 },
        TopologyKind::Hier { groups: 2 },
    ] {
        let res = allgatherv(
            &FabricConfig {
                topology: kind,
                ..FabricConfig::default()
            },
            &inputs,
        );
        assert_eq!(res.gathered, ring.gathered, "{}: bytes changed", kind.label());
        assert!(res.time_ps > 0);
        assert_ne!(
            res.time_ps,
            ring.time_ps,
            "{}: timing did not reflect the topology",
            kind.label()
        );
    }
    // The hierarchy's uplink knob reaches the front too.
    let at = |uplink: f64| {
        allgatherv(
            &FabricConfig {
                topology: TopologyKind::Hier { groups: 2 },
                inter_rack_gbps: Some(uplink),
                ..FabricConfig::default()
            },
            &inputs,
        )
        .time_ps
    };
    assert!(at(0.05) > at(1.0), "uplink bandwidth ignored by the front");
}

#[test]
fn comm_front_and_fabric_ring_agree_bit_for_bit() {
    let mut rng = Pcg32::new(7, 1);
    let inputs = rand_messages(&mut rng, 5, 200);
    let front = ring_allgatherv(&inputs);
    let topo = build_topology(TopologyKind::Ring, 5);
    let mut fabric = Fabric::for_config(&FabricConfig::default(), topo.node_count());
    let sim = topo.allgatherv(&mut fabric, &inputs);
    assert_eq!(front.gathered, sim.gathered);
    assert_eq!(front.traffic, sim.traffic);
}

// ---------------------------------------------------------------------------
// Scale crosschecks: the analytic-vs-sim agreement that the small-p
// property tests establish must survive to the worker counts the
// `repro scale-sweep` actually runs. Phantom payloads keep these
// debug-build-fast; docs/SCALE.md walks through why they are exact.
// ---------------------------------------------------------------------------

/// Uniform 8 KiB phantom gather on the default GigE fabric, trace off.
fn scale_fabric(kind: TopologyKind, p: usize) -> (Box<dyn vgc::fabric::Topology>, Fabric) {
    let cfg = FabricConfig {
        topology: kind,
        ..FabricConfig::default()
    };
    let topo = build_topology(kind, p);
    let mut fabric = Fabric::for_topology(&cfg, &*topo);
    fabric.set_trace(false);
    (topo, fabric)
}

#[test]
fn ring_crosscheck_holds_at_1024_and_2048_nodes() {
    // `FabricConfig::default()` is GigE (1 Gb/s, 50 µs) — the same link
    // `LinkModel::gige()` models, so `T_v` is directly comparable. The
    // simulated ring gather pipelines rounds, so it beats the analytic
    // `T_v` (which charges the full blocked-transfer sum) but can never
    // be faster than half of it at this message size.
    for p in [1024usize, 2048] {
        let sizes = vec![8_192u64; p];
        let (topo, mut fabric) = scale_fabric(TopologyKind::Ring, p);
        let (sim, engine) = gather_sized(&*topo, &mut fabric, &sizes);
        assert_eq!(engine, Engine::Closed, "p={p}: uniform ring should run closed");
        assert_eq!(
            sim.traffic.bytes_sent_per_node,
            ring_gatherv_bytes_per_node(&sizes),
            "p={p}: ring bytes diverged from analytic"
        );
        assert_eq!(sim.events, (p * (p - 1)) as u64, "p={p}: delivery count");

        let model = CostModel::new(p, 1_000_000, LinkModel::gige());
        let bits: Vec<u64> = sizes.iter().map(|b| b * 8).collect();
        let analytic_s = model.t_allgatherv_bits(&bits);
        let ratio = sim.time_secs() / analytic_s;
        assert!(
            (0.5..=1.0 + 1e-9).contains(&ratio),
            "p={p}: sim {} s vs analytic {} s (ratio {ratio})",
            sim.time_secs(),
            analytic_s
        );
    }
}

#[test]
fn torus_and_hier_crosschecks_hold_at_1024_and_2048_nodes() {
    for p in [1024usize, 2048] {
        let sizes = vec![8_192u64; p];

        let (topo, mut fabric) = scale_fabric(TopologyKind::Torus { rows: 0, cols: 0 }, p);
        let (rows, cols) = match topo.kind() {
            TopologyKind::Torus { rows, cols } => (rows, cols),
            other => panic!("torus resolved to {other:?}"),
        };
        let sim = topo.allgatherv_sized(&mut fabric, &sizes);
        assert_eq!(
            sim.traffic.bytes_sent_per_node,
            torus_gatherv_bytes_per_node(&sizes, rows, cols),
            "torus {rows}x{cols}: bytes diverged from analytic"
        );
        assert_eq!(sim.events, (p * (p - 1)) as u64, "torus p={p}: delivery count");

        let cfg = FabricConfig {
            topology: TopologyKind::Hier { groups: 0 },
            inter_rack_gbps: Some(0.5),
            ..FabricConfig::default()
        };
        let topo = build_topology(cfg.topology, p);
        let groups = match topo.kind() {
            TopologyKind::Hier { groups } => groups,
            other => panic!("hier resolved to {other:?}"),
        };
        let spans = group_spans(p, groups);
        let mut fabric = Fabric::for_topology(&cfg, &*topo);
        fabric.set_trace(false);
        let sim = topo.allgatherv_sized(&mut fabric, &sizes);
        assert_eq!(
            sim.traffic.bytes_sent_per_node,
            hier_gatherv_bytes_per_node(&sizes, &spans),
            "hier p={p} g={groups}: bytes diverged from analytic"
        );
        assert_eq!(sim.events, (p * (p - 1)) as u64, "hier p={p}: delivery count");

        let link = cfg.link.to_cost_model();
        let uplink = LinkModel {
            beta: 1e-9 / 0.5,
            latency: link.latency,
        };
        let bound = hier_gather_time_bounds(&link, &uplink, &sizes, &spans);
        assert!(
            bound.brackets(sim.time_secs()),
            "hier p={p} g={groups}: simulated {} s outside [{}, {}] s",
            sim.time_secs(),
            bound.lower_s,
            bound.upper_s
        );
    }
}

/// The event queue's tie-break contract: events popping at the same
/// tick come out in insertion order, no matter which internal queue
/// (per-lane FIFO, overflow heap, or a mix) absorbed the schedule call.
/// 120 000 pending events with times drawn from a tiny range force
/// massive tie populations through both paths.
#[test]
fn simclock_tiebreak_is_deterministic_across_queue_paths() {
    const N: u32 = 120_000;
    const LANES: usize = 64;
    let mut rng = Pcg32::new(97, 3);
    let schedule: Vec<(u64, u32)> = (0..N)
        .map(|id| ((rng.next_u32() % 256) as u64, id))
        .collect();

    let mut heap_only: SimClock<u32> = SimClock::new();
    let mut lanes_only: SimClock<u32> = SimClock::with_lanes(LANES);
    let mut mixed: SimClock<u32> = SimClock::with_lanes(LANES);
    for &(at, id) in &schedule {
        heap_only.schedule(at, id);
        lanes_only.schedule_lane(at, id as usize % LANES, id);
        if id % 2 == 0 {
            mixed.schedule_lane(at, id as usize % LANES, id);
        } else {
            mixed.schedule(at, id);
        }
    }
    assert_eq!(heap_only.pending(), N as usize);
    assert_eq!(lanes_only.pending(), N as usize);

    let drain = |clock: &mut SimClock<u32>| -> Vec<(u64, u32)> {
        let mut out = Vec::with_capacity(N as usize);
        while let Some(ev) = clock.pop() {
            out.push(ev);
        }
        out
    };
    let reference = drain(&mut heap_only);
    assert_eq!(reference.len(), N as usize);
    assert!(
        reference.windows(2).all(|w| w[0].0 <= w[1].0),
        "pop times must be nondecreasing"
    );
    assert_eq!(
        drain(&mut lanes_only),
        reference,
        "lane queues reordered tied events"
    );
    assert_eq!(
        drain(&mut mixed),
        reference,
        "mixing lane and heap scheduling reordered tied events"
    );
    assert_eq!(heap_only.processed(), N as u64);
}

/// A 1024-node hierarchy loses a worker mid-fleet: the collective
/// routes around it and every surviving pair still exchanges exact
/// bytes, with the dead worker's rows/columns masked out.
#[test]
fn hier_1024_routes_around_a_crashed_node() {
    let p = 1024usize;
    let dead = 137usize;
    let inputs: Vec<Vec<u8>> = (0..p)
        .map(|w| {
            let len = 16 + (w * 7) % 49;
            (0..len).map(|i| (w * 31 + i) as u8).collect()
        })
        .collect();
    let cfg = FabricConfig {
        topology: TopologyKind::Hier { groups: 16 },
        ..FabricConfig::default()
    };
    let res = allgatherv_faulty(&cfg, &inputs, &[dead]);
    assert_eq!(res.report.reroutes, 1, "one node loss, one route-around");
    assert!(res.time_ps > 0);
    for dst in 0..p {
        for src in 0..p {
            if dst == dead || src == dead {
                assert!(
                    res.gathered[dst][src].is_empty(),
                    "dead node {dead} left bytes at [{dst}][{src}]"
                );
            } else {
                assert_eq!(
                    res.gathered[dst][src], inputs[src],
                    "corrupt at dst={dst} src={src}"
                );
            }
        }
    }
}

/// The overlap pipeline at fleet scale: a 1024-node hierarchy gathers
/// two buckets bit-exactly, and hiding communication behind compute
/// never costs more than the phased schedule it replaces.
#[test]
fn hier_1024_overlapped_gather_beats_phased() {
    let p = 1024usize;
    let inputs: Vec<Vec<u8>> = (0..p)
        .map(|w| (0..64).map(|i| (w * 13 + i) as u8).collect())
        .collect();
    let cfg = FabricConfig {
        topology: TopologyKind::Hier { groups: 16 },
        segment_bytes: 32,
        ..FabricConfig::default()
    };
    let res = allgatherv_overlapped(&cfg, &inputs, &[1, 1], 40_000_000, 10_000_000);
    assert!(res.buckets >= 2, "two weights should survive coalescing");
    assert!(
        res.schedule.overlapped_ps <= res.schedule.phased_ps,
        "overlap regressed: {} > {}",
        res.schedule.overlapped_ps,
        res.schedule.phased_ps
    );
    for dst in [0usize, 1, 511, p - 1] {
        for src in 0..p {
            assert_eq!(
                res.gathered[dst][src], inputs[src],
                "corrupt at dst={dst} src={src}"
            );
        }
    }
}
