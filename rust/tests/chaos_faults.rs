//! Chaos-fabric integration properties:
//!
//! * a zero-rate (or absent) fault plan is bit-identical to no plan at
//!   all, for every topology — gathered bytes, timing, and counters;
//! * `(seed, plan)` replays are deterministic: same gathered matrix,
//!   same completion time, same `FabricReport`;
//! * link faults (drops, corruption, flaps) are *masked*: retransmits
//!   recover the exact bytes, only timing and counters move;
//! * training under `--on-crash flush-rejoin` is bit-identical to the
//!   fault-free run (worker crashes are masked from the math; the
//!   recovery cost is billed to simulated time);
//! * training under `--on-crash renorm` with a permanent crash
//!   measurably diverges and reports reroutes;
//! * `RunEvent::Fault` / `RunEvent::Degraded` fire at the right steps.
//!
//! The fabric-level tests run everywhere; the trainer tests skip when
//! artifacts are not built (same convention as training_integration).

use vgc::comm::allgatherv::allgatherv;
use vgc::compress::CodecSpec;
use vgc::config::{CrashPolicy, TrainConfig};
use vgc::coordinator::{RunEvent, Trainer};
use vgc::fabric::{FabricConfig, FaultPlan, TopologyKind};
use vgc::runtime::{Client, Manifest};

const ALL_TOPOLOGIES: [TopologyKind; 6] = [
    TopologyKind::Ring,
    TopologyKind::Full,
    TopologyKind::Star,
    TopologyKind::Tree { branch: 2 },
    TopologyKind::Torus { rows: 2, cols: 2 },
    TopologyKind::Hier { groups: 2 },
];

fn msgs(p: usize, base: usize) -> Vec<Vec<u8>> {
    (0..p)
        .map(|i| {
            (0..base + 17 * i)
                .map(|j| ((i * 131 + j) % 251) as u8)
                .collect()
        })
        .collect()
}

fn cfg_for(kind: TopologyKind, spec: &str, seed: u64) -> FabricConfig {
    FabricConfig {
        topology: kind,
        seed,
        faults: FaultPlan::parse(spec).expect("spec parses"),
        ..FabricConfig::default()
    }
}

#[test]
fn silent_plan_is_bit_identical_to_no_plan() {
    // Plans that are armed but never fire must not perturb the
    // simulation at all: the fault RNG is a separate stream, crashes
    // are inert at the transport layer, and a flap window far past the
    // gather's completion is never entered.
    let inputs = msgs(4, 24);
    // The empty plan; membership faults (inert at the transport
    // layer); a flap window opening ~9 ms in when the gather ends in
    // microseconds.
    let silent_specs = ["", "crash:3@100", "flap:0-1@9000..10000"];
    for kind in ALL_TOPOLOGIES {
        let clean = allgatherv(
            &FabricConfig {
                topology: kind,
                ..FabricConfig::default()
            },
            &inputs,
        );
        for spec in silent_specs {
            let silent = allgatherv(&cfg_for(kind, spec, 0), &inputs);
            assert_eq!(silent.gathered, clean.gathered, "{kind:?} '{spec}'");
            assert_eq!(silent.time_ps, clean.time_ps, "{kind:?} '{spec}'");
            assert_eq!(silent.traffic, clean.traffic, "{kind:?} '{spec}'");
            assert!(silent.report.is_clean(), "{kind:?} '{spec}'");
        }
    }
}

#[test]
fn seed_plan_replays_are_deterministic() {
    let inputs = msgs(4, 40);
    for kind in ALL_TOPOLOGIES {
        for seed in [0u64, 7, 1234] {
            let spec = "drop:0-1:0.4,corrupt:1-0:0.3,flap:0-1@0..5";
            let a = allgatherv(&cfg_for(kind, spec, seed), &inputs);
            let b = allgatherv(&cfg_for(kind, spec, seed), &inputs);
            assert_eq!(a.gathered, b.gathered, "{kind:?} seed {seed}");
            assert_eq!(a.time_ps, b.time_ps, "{kind:?} seed {seed}");
            assert_eq!(a.report, b.report, "{kind:?} seed {seed}");
        }
    }
}

#[test]
fn link_faults_are_masked_on_every_topology() {
    // Per-topology edges chosen to sit on gather routes; whichever
    // fire, the gathered bytes must be exactly the fault-free bytes.
    let inputs = msgs(4, 64);
    let specs: [(TopologyKind, &str); 6] = [
        (TopologyKind::Ring, "drop:0-1:0.6,corrupt:1-2:0.5"),
        (TopologyKind::Full, "drop:0-1:0.6,corrupt:1-0:0.5"),
        (TopologyKind::Star, "drop:0-4:0.6,corrupt:4-1:0.5"),
        (TopologyKind::Tree { branch: 2 }, "drop:1-0:0.6,flap:0-1@0..20"),
        (TopologyKind::Torus { rows: 2, cols: 2 }, "drop:0-1:0.6,corrupt:1-0:0.5"),
        (TopologyKind::Hier { groups: 2 }, "drop:2-0:0.6,flap:0-2@0..20"),
    ];
    let mut fired = false;
    for (kind, spec) in specs {
        let clean = allgatherv(
            &FabricConfig {
                topology: kind,
                ..FabricConfig::default()
            },
            &inputs,
        );
        for seed in 0..4u64 {
            let res = allgatherv(&cfg_for(kind, spec, seed), &inputs);
            assert_eq!(
                res.gathered, clean.gathered,
                "{kind:?} seed {seed}: faults leaked into the bytes"
            );
            assert!(res.time_ps >= clean.time_ps, "{kind:?} seed {seed}");
            assert_eq!(
                res.report.retries,
                res.report.drops + res.report.corruptions,
                "{kind:?} seed {seed}: every loss retransmits exactly once"
            );
            fired |= !res.report.is_clean();
        }
    }
    assert!(fired, "no fault fired across any topology/seed");
}

// ---- trainer-level properties (need built artifacts) ----

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

fn mlp_cfg(steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::defaults("mlp");
    cfg.codec = CodecSpec::Vgc {
        alpha: 1.5,
        zeta: 0.999,
    };
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg
}

#[test]
fn flush_rejoin_crash_is_bit_identical_but_billed() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();

    let mut clean = Trainer::new(&client, &man, mlp_cfg(10)).unwrap();
    if clean.workers() < 2 {
        eprintln!("SKIP: single-worker model has no membership to degrade");
        return;
    }
    clean.run(true).unwrap();

    let mut cfg = mlp_cfg(10);
    cfg.on_crash = CrashPolicy::FlushRejoin;
    cfg.fabric.faults = FaultPlan::parse("crash:1@3+2").unwrap();
    let mut faulted = Trainer::new(&client, &man, cfg).unwrap();
    faulted.run(true).unwrap();

    assert_eq!(
        clean.params, faulted.params,
        "flush-rejoin must mask the crash from the training math"
    );
    assert!(
        faulted.sim_comm_ps > clean.sim_comm_ps,
        "rejoin state transfer must be billed to simulated time \
         ({} !> {})",
        faulted.sim_comm_ps,
        clean.sim_comm_ps
    );
    assert!(faulted.fault_report.is_clean());
}

#[test]
fn renorm_permanent_crash_measurably_diverges() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();

    let mut clean = Trainer::new(&client, &man, mlp_cfg(10)).unwrap();
    if clean.workers() < 2 {
        eprintln!("SKIP: single-worker model has no membership to degrade");
        return;
    }
    clean.run(true).unwrap();

    let mut cfg = mlp_cfg(10);
    cfg.fabric.faults = FaultPlan::parse("crash:1@3").unwrap();
    let mut faulted = Trainer::new(&client, &man, cfg).unwrap();
    faulted.run(true).unwrap();

    assert_ne!(
        clean.params, faulted.params,
        "renorm over survivors is a different estimator — params must move"
    );
    assert!(faulted.params.iter().all(|p| p.is_finite()));
    assert!(
        faulted.fault_report.reroutes > 0,
        "degraded gathers must report reroutes"
    );
}

#[test]
fn flush_rejoin_rejects_permanent_worker_crashes() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let probe = Trainer::new(&client, &man, mlp_cfg(2)).unwrap();
    if probe.workers() < 2 {
        return;
    }
    let mut cfg = mlp_cfg(5);
    cfg.on_crash = CrashPolicy::FlushRejoin;
    cfg.fabric.faults = FaultPlan::parse("crash:1@3").unwrap();
    let err = match Trainer::new(&client, &man, cfg) {
        Ok(_) => panic!("permanent worker crash must be rejected under flush-rejoin"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("flush-rejoin"), "{err}");
}

#[test]
fn fault_events_fire_at_plan_steps() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let mut cfg = mlp_cfg(8);
    cfg.fabric.faults = FaultPlan::parse("crash:1@3+2").unwrap();
    let mut t = Trainer::new(&client, &man, cfg).unwrap();
    if t.workers() < 2 {
        return;
    }
    let mut faults: Vec<(u64, String, usize)> = Vec::new();
    let mut degraded: Vec<(u64, usize, usize)> = Vec::new();
    t.run_with(true, &mut |ev| {
        match ev {
            RunEvent::Fault { step, kind, node } => faults.push((step, kind.to_string(), node)),
            RunEvent::Degraded { step, live, total } => degraded.push((step, live, total)),
            _ => {}
        }
        true
    })
    .unwrap();
    assert_eq!(
        faults,
        vec![(3, "crash".to_string(), 1), (5, "rejoin".to_string(), 1)]
    );
    let total = t.workers();
    assert_eq!(degraded, vec![(3, total - 1, total), (4, total - 1, total)]);
}
