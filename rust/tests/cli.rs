//! Launcher CLI integration: run the real `repro` binary end to end.

use std::process::Command;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn help_prints_usage() {
    let out = repro().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("table1"));
    assert!(text.contains("fabric-sweep"));
}

#[test]
fn fabric_sweep_runs_end_to_end() {
    let json_path = std::env::temp_dir().join("vgc_fabric_sweep.json");
    let out = repro()
        .args([
            "fabric-sweep",
            "--topologies", "ring,star",
            "--workers", "4",
            "--bandwidth-gbps", "1,10",
            "--codecs", "none+vgc:alpha=2",
            "--n", "4096",
            "--out", json_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("| topology |"), "{text}");
    assert!(text.contains("speedup"), "{text}");
    assert!(text.contains("| ring |"), "{text}");
    assert!(text.contains("| star |"), "{text}");
    // 2 topologies × 2 bandwidths × 2 codecs × 1 worker count.
    let json = std::fs::read_to_string(&json_path).unwrap();
    let rows = vgc::util::json::Json::parse(&json).unwrap();
    assert_eq!(rows.as_arr().unwrap().len(), 8);
    assert!(json.contains("sim_ms"));
    assert!(json.contains("max_link_bytes"));
}

#[test]
fn fabric_sweep_runs_torus_and_hier_end_to_end() {
    let json_path = std::env::temp_dir().join("vgc_fabric_sweep_new.json");
    let out = repro()
        .args([
            "fabric-sweep",
            "--topologies", "torus,hier:2",
            "--workers", "4",
            "--bandwidth-gbps", "1",
            "--inter-rack-gbps", "0.1",
            "--segment-bytes", "2048",
            "--codecs", "none+vgc:alpha=2",
            "--n", "4096",
            "--out", json_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    // Auto torus dims resolve in the report; hier keeps its groups.
    assert!(text.contains("| torus:2x2 |"), "{text}");
    assert!(text.contains("| hier:2 |"), "{text}");
    assert!(text.contains("segment 2048 B"), "{text}");
    let json = std::fs::read_to_string(&json_path).unwrap();
    let rows = vgc::util::json::Json::parse(&json).unwrap();
    // 2 topologies × 1 bandwidth × 1 uplink × 2 codecs.
    assert_eq!(rows.as_arr().unwrap().len(), 4);
    assert!(json.contains("inter_rack_gbps"));
}

#[test]
fn fabric_sweep_rejects_bad_topology() {
    let out = repro()
        .args(["fabric-sweep", "--topologies", "moebius"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("topology"), "{err}");
    // The error enumerates the accepted set, new topologies included.
    assert!(err.contains("torus"), "{err}");
    assert!(err.contains("hier"), "{err}");

    // A pinned torus shape that cannot host the worker count is a CLI
    // error, not a panic.
    let out = repro()
        .args(["fabric-sweep", "--topologies", "torus:3x3", "--workers", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("torus 3x3"), "{err}");
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = repro().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn unknown_flag_is_rejected() {
    let out = repro().args(["train", "--modell", "mlp"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown flag") || err.contains("model"), "{err}");
}

#[test]
fn costmodel_reports_linear_regime() {
    let out = repro().arg("costmodel").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("c > p/2"));
    assert!(text.contains("speedup"));
}

#[test]
fn inspect_lists_models() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let out = repro().arg("inspect").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for model in ["mlp", "vgg_tiny", "resnet_mini", "transformer"] {
        assert!(text.contains(model), "missing {model} in:\n{text}");
    }
}

#[test]
fn short_train_run_emits_summary_and_curve() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let curve = std::env::temp_dir().join("vgc_cli_curve.csv");
    let out = repro()
        .args([
            "train", "--model", "mlp", "--codec", "vgc:alpha=1.5", "--steps", "5",
            "--eval-every", "0", "--log-every", "0",
            "--loss-curve", curve.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("compression ratio"));
    let csv = std::fs::read_to_string(&curve).unwrap();
    assert_eq!(csv.lines().count(), 6); // header + 5 steps
}

#[test]
fn bench_codecs_runs_and_emits_json() {
    let json_path = std::env::temp_dir().join("vgc_bench_codecs.json");
    let out = repro()
        .env("VGC_BENCH_FAST", "1")
        .args([
            "bench-codecs",
            "--n", "20000",
            "--group", "256",
            "--workers", "3",
            "--threads", "1,2",
            "--codecs", "vgc:alpha=1.5+strom:tau=0.01",
            "--json", json_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("| codec |"), "{text}");
    assert!(text.contains("speedup"), "{text}");
    let json = std::fs::read_to_string(&json_path).unwrap();
    let j = vgc::util::json::Json::parse(&json).unwrap();
    let rows = j.expect("rows").unwrap();
    assert_eq!(rows.as_arr().unwrap().len(), 4); // 2 codecs × 2 widths
    // The repro binary installs the counting allocator, so allocation
    // counts must be real numbers (not null) in at least the serial rows.
    assert!(json.contains("allocs_per_step"));
}

#[test]
fn bench_codecs_rejects_bad_flags() {
    let out = repro()
        .args(["bench-codecs", "--threads", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = repro()
        .args(["bench-codecs", "--codecs", "qsgd:bits=0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn train_with_parallel_codec_engine_keeps_sync() {
    if !have_artifacts() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    // verify_sync cross-decodes serially every step: with
    // --codec-threads 2 this asserts engine == serial bit-for-bit on a
    // live training run.
    let out = repro()
        .args([
            "train", "--model", "mlp", "--codec", "vgc:alpha=1.5", "--steps", "5",
            "--eval-every", "0", "--log-every", "0",
            "--codec-threads", "2", "--verify-sync",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("codec-threads=2"), "{text}");
    assert!(text.contains("compression ratio"));
}

#[test]
fn fig3_from_results_converts_json() {
    let dir = std::env::temp_dir();
    let json = r#"[{"table":"table1","method":"vgc alpha=1","optimizer":"adam",
        "accuracy":0.9,"final_loss":0.1,"compression":120.5,"bits_ratio":130.0}]"#;
    let in_path = dir.join("vgc_fig3_in.json");
    let out_path = dir.join("vgc_fig3_out.csv");
    std::fs::write(&in_path, json).unwrap();
    let out = repro()
        .args([
            "fig3", "--from", in_path.to_str().unwrap(),
            "--out", out_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let csv = std::fs::read_to_string(&out_path).unwrap();
    assert!(csv.contains("table1:vgc alpha=1,adam,0.9"), "{csv}");
}
