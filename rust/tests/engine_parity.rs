//! Engine parity: the parallel sharded codec engine must be byte- and
//! bit-identical to the serial path for every codec, layout, thread
//! count and multi-step stream — messages, decoded updates, stats and
//! residual state alike. This is the contract that lets the trainer
//! flip `--codec-threads` without perturbing training by a single ULP.

use vgc::compress::{Codec, CodecEngine, CodecSpec};
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::rng::Pcg32;

/// Every spec the CLI can name (the full wire-format zoo).
fn all_specs() -> Vec<CodecSpec> {
    vec![
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
        CodecSpec::VgcCompact { alpha: 1.5, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.01 },
        CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 },
        CodecSpec::Qsgd { bits: 2, bucket: 128 },
        CodecSpec::TernGrad,
        CodecSpec::OneBit,
        CodecSpec::Adaptive { pi: 0.05 },
    ]
}

/// One generated case: a worker-count, layout shape and a multi-step
/// per-worker stream of (gsum, gsumsq) pairs.
type Stream = Vec<Vec<(Vec<f32>, Vec<f32>)>>;

fn gen_case(rng: &mut Pcg32) -> (usize, usize, usize, Stream) {
    let n = testkit::usize_in(rng, 1, 300);
    let group = testkit::usize_in(rng, 1, 64);
    let p = testkit::usize_in(rng, 1, 8);
    let steps = testkit::usize_in(rng, 1, 4);
    let stream: Stream = (0..steps)
        .map(|_| {
            (0..p)
                .map(|_| {
                    let g = testkit::gradient_vec(rng, n);
                    let q: Vec<f32> = g.iter().map(|x| x * x * 0.7).collect();
                    (g, q)
                })
                .collect()
        })
        .collect();
    (n, group, p, stream)
}

fn run_parity(
    spec: &CodecSpec,
    threads: usize,
    n: usize,
    group: usize,
    p: usize,
    stream: &Stream,
) -> Result<(), String> {
    let layout = Layout::uniform(n, group);
    // Identical seeds => identical stochastic codecs on both sides.
    let mut serial: Vec<Box<dyn Codec>> =
        (0..p).map(|w| spec.build(&layout, w as u64)).collect();
    let mut par: Vec<Box<dyn Codec>> =
        (0..p).map(|w| spec.build(&layout, w as u64)).collect();
    let mut engine = CodecEngine::new(threads);
    let mut out_s = vec![0.0f32; n];
    let mut out_p = vec![0.0f32; n];

    for (step, inputs) in stream.iter().enumerate() {
        // Serial reference: owned messages + sequential decode.
        let msgs: Vec<vgc::compress::Message> = serial
            .iter_mut()
            .zip(inputs)
            .map(|(c, (g, q))| c.encode_step(g, q))
            .collect();
        for x in out_s.iter_mut() {
            *x = 0.0;
        }
        for m in &msgs {
            serial[0]
                .decode_into(&m.bytes, &mut out_s)
                .map_err(|e| format!("serial decode: {e}"))?;
        }

        // Engine path.
        {
            let mut refs: Vec<&mut dyn Codec> =
                par.iter_mut().map(|c| &mut **c).collect();
            let gs: Vec<&[f32]> = inputs.iter().map(|(g, _)| g.as_slice()).collect();
            let qs: Vec<&[f32]> = inputs.iter().map(|(_, q)| q.as_slice()).collect();
            engine.encode_all(&mut refs, &gs, &qs);
        }
        for w in 0..p {
            if engine.messages()[w] != msgs[w].bytes {
                return Err(format!(
                    "step {step} worker {w}: wire bytes diverged (threads={threads})"
                ));
            }
            if engine.stats()[w].elements != msgs[w].elements
                || engine.stats()[w].payload_bits != msgs[w].payload_bits
            {
                return Err(format!("step {step} worker {w}: stats diverged"));
            }
        }
        let gathered: Vec<Vec<u8>> = engine.messages().to_vec();
        engine
            .decode_all(&*par[0], &gathered, &mut out_p)
            .map_err(|e| format!("engine decode: {e}"))?;
        for i in 0..n {
            if out_s[i].to_bits() != out_p[i].to_bits() {
                return Err(format!(
                    "step {step} element {i}: update diverged {} vs {} (threads={threads})",
                    out_s[i], out_p[i]
                ));
            }
        }
    }
    // Residual state must track exactly too (delayed-update codecs).
    for w in 0..p {
        let (a, b) = (serial[w].residual_l1(), par[w].residual_l1());
        if a != b {
            return Err(format!("worker {w}: residual diverged {a} vs {b}"));
        }
    }
    Ok(())
}

#[test]
fn engine_matches_serial_for_every_codec_and_thread_count() {
    for spec in all_specs() {
        testkit::for_all(
            &format!("engine parity [{}]", spec.label()),
            gen_case,
            |(n, group, p, stream)| {
                for threads in [1usize, 2, 7] {
                    run_parity(&spec, threads, *n, *group, *p, stream)?;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn pooled_shard_path_is_exercised_when_threads_exceed_workers() {
    // p < threads routes through Codec::encode_step_pooled (intra-worker
    // group shards). Pin that configuration explicitly for the sharded
    // codecs.
    for spec in [
        CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 },
        CodecSpec::VgcCompact { alpha: 1.0, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.005 },
        CodecSpec::Hybrid { tau: 0.005, alpha: 1.5, zeta: 0.999 },
        CodecSpec::Adaptive { pi: 0.1 },
    ] {
        testkit::for_all(
            &format!("pooled shard parity [{}]", spec.label()),
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 500);
                let group = testkit::usize_in(rng, 1, 48);
                let steps = testkit::usize_in(rng, 1, 3);
                let stream: Stream = (0..steps)
                    .map(|_| {
                        (0..2usize)
                            .map(|_| {
                                let g = testkit::gradient_vec(rng, n);
                                let q: Vec<f32> = g.iter().map(|x| x * x).collect();
                                (g, q)
                            })
                            .collect()
                    })
                    .collect();
                (n, group, stream)
            },
            |(n, group, stream)| run_parity(&spec, 7, *n, *group, 2, stream),
        );
    }
}

#[test]
fn multi_worker_messages_differ_but_updates_agree_across_thread_counts() {
    // Sanity: different thread counts on the same stream produce the
    // same bytes as each other (not just as serial).
    let spec = CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 };
    let n = 257;
    let p = 3;
    let layout = Layout::uniform(n, 19);
    let mut rng = Pcg32::new(99, 4);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..p)
        .map(|_| {
            let g = testkit::gradient_vec(&mut rng, n);
            let q: Vec<f32> = g.iter().map(|x| x * x).collect();
            (g, q)
        })
        .collect();
    let mut all_msgs: Vec<Vec<Vec<u8>>> = Vec::new();
    for threads in [1usize, 2, 7] {
        let mut codecs: Vec<Box<dyn Codec>> =
            (0..p).map(|w| spec.build(&layout, w as u64)).collect();
        let mut engine = CodecEngine::new(threads);
        let mut refs: Vec<&mut dyn Codec> =
            codecs.iter_mut().map(|c| &mut **c).collect();
        let gs: Vec<&[f32]> = inputs.iter().map(|(g, _)| g.as_slice()).collect();
        let qs: Vec<&[f32]> = inputs.iter().map(|(_, q)| q.as_slice()).collect();
        engine.encode_all(&mut refs, &gs, &qs);
        all_msgs.push(engine.messages().to_vec());
    }
    assert_eq!(all_msgs[0], all_msgs[1]);
    assert_eq!(all_msgs[1], all_msgs[2]);
}
