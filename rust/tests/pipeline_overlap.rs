//! Integration: the bucketed overlap pipeline is a pure scheduling
//! change. For every codec, bucket size (including the 1-byte
//! degenerate case) and topology, trained parameters must be
//! bit-identical to the phased path, and the reported overlapped step
//! time must never exceed the phased step time.

use vgc::compress::CodecSpec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::fabric::TopologyKind;
use vgc::runtime::{Client, Manifest};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

fn cfg(codec: CodecSpec, bucket_bytes: usize, overlap: bool) -> TrainConfig {
    let mut cfg = TrainConfig::defaults("mlp");
    cfg.codec = codec;
    cfg.steps = 6;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.verify_sync = true;
    cfg.bucket_bytes = bucket_bytes;
    cfg.overlap = overlap;
    cfg
}

struct Run {
    params: Vec<f32>,
    sim_phased_ps: u64,
    sim_overlap_ps: u64,
}

fn run(client: &Client, man: &Manifest, cfg: TrainConfig) -> Run {
    let mut t = Trainer::new(client, man, cfg).unwrap();
    t.run(true).unwrap();
    Run {
        params: t.params.clone(),
        sim_phased_ps: t.sim_phased_ps,
        sim_overlap_ps: t.sim_overlap_ps,
    }
}

fn all_codecs() -> Vec<CodecSpec> {
    vec![
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
        CodecSpec::VgcCompact { alpha: 1.5, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.001 },
        CodecSpec::Hybrid { tau: 0.001, alpha: 2.0, zeta: 0.999 },
        CodecSpec::Qsgd { bits: 4, bucket: 128 },
        CodecSpec::TernGrad,
        CodecSpec::OneBit,
        CodecSpec::Adaptive { pi: 0.01 },
    ]
}

#[test]
fn bucketed_pipeline_is_bit_identical_for_every_codec() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    for codec in all_codecs() {
        let label = codec.label();
        let base = run(&client, &man, cfg(codec.clone(), 0, false));
        // The legacy path reports the same span phased and overlapped.
        assert_eq!(
            base.sim_phased_ps, base.sim_overlap_ps,
            "{label}: phased path must report equal spans"
        );
        // 1-byte buckets (one bucket per layer group), a realistic
        // fusion threshold, and overlap-without-fusion (one bucket).
        for (bytes, overlap) in [(1usize, true), (4096, true), (4096, false), (0, true)] {
            let piped = run(&client, &man, cfg(codec.clone(), bytes, overlap));
            assert_eq!(
                base.params, piped.params,
                "{label} bucket={bytes} overlap={overlap}: pipeline changed the math"
            );
            assert!(
                piped.sim_overlap_ps <= piped.sim_phased_ps,
                "{label} bucket={bytes} overlap={overlap}: overlapped {} > phased {}",
                piped.sim_overlap_ps,
                piped.sim_phased_ps
            );
        }
    }
}

#[test]
fn overlap_is_topology_invariant() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let codecs = [
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
    ];
    for codec in codecs {
        let label = codec.label();
        let base = run(&client, &man, cfg(codec.clone(), 0, false));
        for topology in [
            TopologyKind::Ring,
            TopologyKind::Star,
            TopologyKind::Torus { rows: 0, cols: 0 },
            TopologyKind::Hier { groups: 2 },
        ] {
            let mut c = cfg(codec.clone(), 2048, true);
            c.fabric.topology = topology;
            let piped = run(&client, &man, c);
            assert_eq!(
                base.params, piped.params,
                "{label} on {topology:?}: pipeline changed the math"
            );
            assert!(
                piped.sim_overlap_ps <= piped.sim_phased_ps,
                "{label} on {topology:?}: overlapped exceeds phased"
            );
        }
    }
}
