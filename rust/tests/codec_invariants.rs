//! Cross-codec property suite: the invariants DESIGN.md §5 calls out,
//! exercised over generated gradient streams and the real allgatherv
//! fabric (no XLA dependency — these run everywhere).

use vgc::comm::allgatherv::ring_allgatherv;
use vgc::compress::{Codec, CodecSpec};
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn all_specs() -> Vec<CodecSpec> {
    vec![
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 },
        CodecSpec::Vgc { alpha: 2.0, zeta: 0.99 },
        CodecSpec::Strom { tau: 0.05 },
        CodecSpec::Hybrid { tau: 0.05, alpha: 1.5, zeta: 0.999 },
        CodecSpec::Qsgd { bits: 2, bucket: 64 },
        CodecSpec::Qsgd { bits: 4, bucket: 512 },
        CodecSpec::TernGrad,
    ]
}

/// Drive one codec over a stream, decoding every message; returns the
/// total decoded update.
fn drive(codec: &mut Box<dyn Codec>, stream: &[Vec<f32>], n: usize) -> Vec<f32> {
    let mut total = vec![0.0f32; n];
    for g in stream {
        let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
        let msg = codec.encode_step(g, &sq);
        codec.decode_into(&msg.bytes, &mut total).unwrap();
    }
    total
}

#[test]
fn every_codec_roundtrips_its_own_messages() {
    for spec in all_specs() {
        testkit::for_all(
            &format!("roundtrip {}", spec.label()),
            |rng: &mut Pcg32| {
                let n = testkit::usize_in(rng, 1, 150);
                let steps = testkit::usize_in(rng, 1, 8);
                (0..steps)
                    .map(|_| testkit::gradient_vec(rng, n))
                    .collect::<Vec<_>>()
            },
            |stream| {
                let n = stream[0].len();
                let layout = Layout::uniform(n, 13);
                let mut codec = spec.build(&layout, 1);
                let total = drive(&mut codec, stream, n);
                if total.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite decode".into())
                }
            },
        );
    }
}

#[test]
fn decode_is_stateless_and_deterministic() {
    // Decoding the same message twice into two buffers gives identical
    // results, regardless of intervening encodes.
    for spec in all_specs() {
        let n = 97;
        let layout = Layout::uniform(n, 10);
        let mut codec = spec.build(&layout, 2);
        let mut rng = Pcg32::new(3, 3);
        let g = testkit::gradient_vec(&mut rng, n);
        let sq: Vec<f32> = g.iter().map(|x| x * x).collect();
        let msg = codec.encode_step(&g, &sq);
        let mut out1 = vec![0.0f32; n];
        codec.decode_into(&msg.bytes, &mut out1).unwrap();
        // Encode more steps (mutates codec state).
        codec.encode_step(&g, &sq);
        let mut out2 = vec![0.0f32; n];
        codec.decode_into(&msg.bytes, &mut out2).unwrap();
        assert_eq!(out1, out2, "{}", spec.label());
    }
}

#[test]
fn allgatherv_then_decode_equals_direct_decode() {
    // The synchrony invariant at codec level: decoding the gathered
    // messages equals decoding the originals, on every worker.
    let p = 5;
    let n = 120;
    let layout = Layout::uniform(n, 11);
    let spec = CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 };
    let mut codecs: Vec<Box<dyn Codec>> =
        (0..p).map(|w| spec.build(&layout, w as u64)).collect();
    let mut rng = Pcg32::new(17, 4);

    for _ in 0..6 {
        let msgs: Vec<Vec<u8>> = (0..p)
            .map(|w| {
                let g = testkit::gradient_vec(&mut rng, n);
                let sq = vec![0.0; n];
                let _ = w;
                codecs[w].encode_step(&g, &sq).bytes
            })
            .collect();
        let mut direct = vec![0.0f32; n];
        for m in &msgs {
            codecs[0].decode_into(m, &mut direct).unwrap();
        }
        let res = ring_allgatherv(&msgs);
        for dst in 0..p {
            let mut via_ring = vec![0.0f32; n];
            for m in &res.gathered[dst] {
                codecs[dst].decode_into(m, &mut via_ring).unwrap();
            }
            assert_eq!(direct, via_ring, "worker {dst} desync");
        }
    }
}

#[test]
fn hybrid_conservation_with_quantized_sends() {
    // Hybrid sends exact ±τ quanta, so conservation is exact:
    // decoded_total + residual == accumulated stream.
    testkit::for_all(
        "hybrid conservation",
        |rng: &mut Pcg32| {
            let n = testkit::usize_in(rng, 1, 60);
            let steps = testkit::usize_in(rng, 1, 25);
            let stream: Vec<Vec<f32>> =
                (0..steps).map(|_| testkit::gradient_vec(rng, n)).collect();
            (testkit::f32_in(rng, 0.01, 0.3), stream)
        },
        |(tau, stream)| {
            let n = stream[0].len();
            let layout = Layout::uniform(n, 8);
            let mut codec = vgc::compress::hybrid::HybridCodec::new(
                layout, *tau, 1.0, 1.0, // zeta=1: no decay, exact bookkeeping
            );
            let mut decoded = vec![0.0f32; n];
            for g in stream {
                let sq: Vec<f32> = g.iter().map(|x| x * x).collect();
                let msg = vgc::compress::Codec::encode_step(&mut codec, g, &sq);
                vgc::compress::Codec::decode_into(&codec, &msg.bytes, &mut decoded)
                    .map_err(|e| e.to_string())?;
            }
            for i in 0..n {
                let total: f32 = stream.iter().map(|g| g[i]).sum();
                let got = decoded[i] + codec.r()[i];
                if (got - total).abs() > 1e-3 * (1.0 + total.abs()) {
                    return Err(format!("i={i}: {got} vs {total}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn corrupt_messages_are_rejected_not_misdecoded() {
    // Failure injection: truncation and bit-flips must either error or
    // decode within the message's own bounds — never panic, never write
    // out of range (the decode APIs take &mut [f32] of exactly N).
    for spec in all_specs() {
        let n = 64;
        let layout = Layout::uniform(n, 16);
        let mut codec = spec.build(&layout, 3);
        let mut rng = Pcg32::new(9, 9);
        let g = testkit::gradient_vec(&mut rng, n);
        let sq: Vec<f32> = g.iter().map(|x| x * x).collect();
        let msg = codec.encode_step(&g, &sq);
        if msg.bytes.is_empty() {
            continue;
        }
        let mut out = vec![0.0f32; n];
        // Truncation: must not panic (error is fine).
        let _ = codec.decode_into(&msg.bytes[..msg.bytes.len() / 2], &mut out);
        // Random bit flips: must not panic.
        for trial in 0..20 {
            let mut bad = msg.bytes.clone();
            let pos = (trial * 7919) % bad.len();
            bad[pos] ^= 0xA5;
            let mut out = vec![0.0f32; n];
            let _ = codec.decode_into(&bad, &mut out);
        }
    }
}

#[test]
fn stochastic_codecs_differ_across_workers_deterministic_within() {
    // QSGD/TernGrad rounding streams: different worker seeds must give
    // different messages (independence), same seed identical (replay).
    for spec in [CodecSpec::Qsgd { bits: 2, bucket: 32 }, CodecSpec::TernGrad] {
        let n = 256;
        let layout = Layout::uniform(n, 32);
        let mut rng = Pcg32::new(11, 11);
        let g = testkit::gradient_vec(&mut rng, n);
        let sq = vec![0.0f32; n];
        let m0a = spec.build(&layout, 0).encode_step(&g, &sq);
        let m0b = spec.build(&layout, 0).encode_step(&g, &sq);
        let m1 = spec.build(&layout, 1).encode_step(&g, &sq);
        assert_eq!(m0a.bytes, m0b.bytes, "{} not replayable", spec.label());
        assert_ne!(m0a.bytes, m1.bytes, "{} workers correlated", spec.label());
    }
}

#[test]
fn vgc_total_delivery_approaches_stream_mass_on_persistent_gradients() {
    // A persistent constant gradient must eventually be delivered: over
    // many steps the decoded total approaches steps·g within the
    // quantizer bracket plus at most a few steps' worth of residual.
    let n = 32;
    let layout = Layout::uniform(n, 8);
    let mut codec = CodecSpec::Vgc { alpha: 2.0, zeta: 0.999 }.build(&layout, 0);
    let g = vec![0.02f32; n];
    let sq = vec![0.0004f32; n]; // per-step v increment = g² (B=1-like)
    let steps = 200;
    let mut decoded = vec![0.0f32; n];
    for _ in 0..steps {
        let msg = codec.encode_step(&g, &sq);
        codec.decode_into(&msg.bytes, &mut decoded).unwrap();
    }
    let want = 0.02 * steps as f32;
    for (i, &d) in decoded.iter().enumerate() {
        assert!(
            d > want * 0.5 && d < want * 1.4,
            "i={i}: delivered {d} of {want}"
        );
    }
}

#[test]
fn message_sizes_account_for_elements() {
    // Wire accounting: sparse codec messages carry exactly 4 bytes per
    // element plus declared headers; elements never exceeds N.
    testkit::for_all(
        "message accounting",
        |rng: &mut Pcg32| {
            let n = testkit::usize_in(rng, 1, 300);
            testkit::gradient_vec(rng, n)
        },
        |g| {
            let n = g.len();
            let layout = Layout::uniform(n, 17);
            for spec in [
                CodecSpec::Strom { tau: 0.01 },
                CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 },
            ] {
                let mut codec = spec.build(&layout, 0);
                let msg = codec.encode_step(g, &vec![0.0; n]);
                if msg.elements > n as u64 {
                    return Err(format!("{}: {} > N", spec.label(), msg.elements));
                }
                if msg.payload_bits != msg.elements * 32 {
                    return Err(format!("{}: payload bits mismatch", spec.label()));
                }
                if (msg.bytes.len() as u64) < msg.elements * 4 {
                    return Err(format!("{}: wire smaller than payload", spec.label()));
                }
            }
            Ok(())
        },
    );
}
