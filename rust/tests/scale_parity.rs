//! Scale-tier parity: every fast path the 4096-node sweep relies on is
//! pinned bit- and tick-identical to the reference event loop here, at
//! sizes small enough to run the reference.
//!
//! Two fast tiers exist (docs/SCALE.md):
//!
//! * **phantom payloads** — `allgatherv_sized` runs the identical
//!   protocol/engine code with sized-but-bodyless messages. Pinned
//!   against real-bytes `allgatherv` for every topology, codec-shaped
//!   size distributions, segmentation, jitter, and stragglers: same
//!   event clock, same event count, same per-node/per-link byte
//!   counters.
//! * **closed-form replay** — `gather_sized` skips the event loop
//!   entirely for ring/full-mesh on uniform fabrics. Pinned
//!   tick-identical to the event loop, and pinned to *disengage* the
//!   moment the fabric stops being uniform (one jittered link).

use vgc::compress::CodecSpec;
use vgc::fabric::{
    build_topology, gather_sized, Engine, Fabric, FabricConfig, LinkSpec, Straggler,
    TopologyKind,
};
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn all_kinds() -> Vec<TopologyKind> {
    vec![
        TopologyKind::Ring,
        TopologyKind::Full,
        TopologyKind::Star,
        TopologyKind::Tree { branch: 3 },
        TopologyKind::Torus { rows: 0, cols: 0 },
        TopologyKind::Torus3 { x: 0, y: 0, z: 0 },
        TopologyKind::Hier { groups: 0 },
        TopologyKind::Hier { groups: 2 },
        TopologyKind::Dragonfly { groups: 0 },
        TopologyKind::Dragonfly { groups: 3 },
    ]
}

/// Per-worker wire messages from a real codec pass — the size
/// distributions the sweeps actually gather (dense, sparse, skewed).
fn codec_messages(spec: &CodecSpec, p: usize, n: usize, seed: u64) -> Vec<Vec<u8>> {
    let layout = Layout::uniform(n, 64);
    (0..p)
        .map(|w| {
            let mut rng = Pcg32::new(seed, w as u64);
            let g = testkit::gradient_vec(&mut rng, n);
            let sq: Vec<f32> = g.iter().map(|x| x * x * 0.5).collect();
            let mut codec = spec.build(&layout, seed.wrapping_add(w as u64));
            codec.encode_step(&g, &sq).bytes
        })
        .collect()
}

fn codec_sample() -> Vec<CodecSpec> {
    vec![
        CodecSpec::None,
        CodecSpec::Vgc {
            alpha: 2.0,
            zeta: 0.999,
        },
        CodecSpec::Strom { tau: 0.01 },
    ]
}

/// Phantom (sized) gathers must be indistinguishable from real-bytes
/// gathers in every observable except the payload matrix: identical
/// event clock, event count, and byte counters — across every
/// topology, codec-shaped sizes, segmentation, jitter, stragglers.
#[test]
fn phantom_gathers_are_tick_identical_to_real_gathers() {
    testkit::for_all(
        "phantom == real (clock, events, traffic)",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 2, 9);
            let codec = codec_sample()[testkit::usize_in(rng, 0, 2)].clone();
            let seg = [0usize, 7][testkit::usize_in(rng, 0, 1)];
            let jitter = [0.0f64, 15.0][testkit::usize_in(rng, 0, 1)];
            let seed = testkit::usize_in(rng, 0, 10_000) as u64;
            (p, codec, seg, jitter, seed)
        },
        |(p, codec, seg, jitter, seed)| {
            let msgs = codec_messages(codec, *p, 256, *seed);
            let sizes: Vec<u64> = msgs.iter().map(|m| m.len() as u64).collect();
            for kind in all_kinds() {
                if kind.validate(*p).is_err() {
                    continue;
                }
                let cfg = FabricConfig {
                    topology: kind,
                    link: LinkSpec {
                        bandwidth_gbps: 1.0,
                        latency_us: 10.0,
                        jitter_us: *jitter,
                    },
                    segment_bytes: *seg,
                    seed: *seed,
                    stragglers: vec![Straggler {
                        node: 1,
                        slowdown: 2.0,
                    }],
                    ..FabricConfig::default()
                };
                let topo = build_topology(kind, *p);
                let mut real_fabric = Fabric::for_topology(&cfg, &*topo);
                let real = topo.allgatherv(&mut real_fabric, &msgs);
                let mut ghost_fabric = Fabric::for_topology(&cfg, &*topo);
                let ghost = topo.allgatherv_sized(&mut ghost_fabric, &sizes);

                let label = kind.label();
                if ghost.time_ps != real.time_ps {
                    return Err(format!(
                        "{label}: phantom clock {} != real {}",
                        ghost.time_ps, real.time_ps
                    ));
                }
                if ghost.events != real.events {
                    return Err(format!(
                        "{label}: phantom events {} != real {}",
                        ghost.events, real.events
                    ));
                }
                if ghost.traffic != real.traffic {
                    return Err(format!("{label}: traffic counters diverged"));
                }
                if !ghost.gathered.is_empty() {
                    return Err(format!("{label}: phantom materialized payloads"));
                }
            }
            Ok(())
        },
    );
}

/// The closed-form replay must be tick-identical to the event loop for
/// every codec-shaped size distribution — and bit-identical in every
/// traffic counter, which is what the scale sweep asserts at 4096.
#[test]
fn closed_replay_is_tick_identical_for_ring_and_mesh() {
    testkit::for_all(
        "closed == event loop (ring, full)",
        |rng: &mut Pcg32| {
            let p = testkit::usize_in(rng, 2, 11);
            let codec = codec_sample()[testkit::usize_in(rng, 0, 2)].clone();
            let seed = testkit::usize_in(rng, 0, 10_000) as u64;
            (p, codec, seed)
        },
        |(p, codec, seed)| {
            let sizes: Vec<u64> = codec_messages(codec, *p, 256, *seed)
                .iter()
                .map(|m| m.len() as u64)
                .collect();
            for kind in [TopologyKind::Ring, TopologyKind::Full] {
                let cfg = FabricConfig {
                    topology: kind,
                    seed: *seed,
                    ..FabricConfig::default()
                };
                let topo = build_topology(kind, *p);

                let mut closed_fabric = Fabric::for_topology(&cfg, &*topo);
                closed_fabric.set_trace(false);
                let (closed, engine) = gather_sized(&*topo, &mut closed_fabric, &sizes);
                if engine != Engine::Closed {
                    return Err(format!(
                        "{}: uniform fabric fell back to the event loop: {:?}",
                        kind.label(),
                        closed_fabric.full_loop_reason()
                    ));
                }

                let mut event_fabric = Fabric::for_topology(&cfg, &*topo);
                let event = topo.allgatherv_sized(&mut event_fabric, &sizes);

                let label = kind.label();
                if closed.time_ps != event.time_ps {
                    return Err(format!(
                        "{label}: closed clock {} != event {}",
                        closed.time_ps, event.time_ps
                    ));
                }
                if closed.events != event.events {
                    return Err(format!(
                        "{label}: closed events {} != event {}",
                        closed.events, event.events
                    ));
                }
                if closed.traffic != event.traffic {
                    return Err(format!("{label}: traffic counters diverged"));
                }
            }
            Ok(())
        },
    );
}

/// The fallback boundary: a single non-default link disengages the
/// closed tier, and the event loop it falls back to produces the same
/// counters it always did.
#[test]
fn one_jittered_link_disengages_the_closed_tier() {
    let sizes: Vec<u64> = (0..6u64).map(|w| 100 + w * 31).collect();
    let uniform = FabricConfig::default();
    let jittered = FabricConfig {
        link_overrides: vec![(
            2,
            3,
            LinkSpec {
                bandwidth_gbps: uniform.link.bandwidth_gbps,
                latency_us: uniform.link.latency_us,
                jitter_us: 25.0,
            },
        )],
        ..FabricConfig::default()
    };
    let topo = build_topology(TopologyKind::Ring, 6);

    let mut f = Fabric::for_topology(&uniform, &*topo);
    f.set_trace(false);
    let (_, engine) = gather_sized(&*topo, &mut f, &sizes);
    assert_eq!(engine, Engine::Closed, "uniform fabric should run closed");

    let mut f = Fabric::for_topology(&jittered, &*topo);
    f.set_trace(false);
    assert!(
        f.full_loop_reason().is_some(),
        "an overridden link must force the full loop"
    );
    let (fell_back, engine) = gather_sized(&*topo, &mut f, &sizes);
    assert_eq!(engine, Engine::Event);
    // The fallback is the ordinary event loop — identical to calling it
    // directly on an identically-configured fabric.
    let mut f2 = Fabric::for_topology(&jittered, &*topo);
    f2.set_trace(false);
    let direct = topo.allgatherv_sized(&mut f2, &sizes);
    assert_eq!(fell_back.time_ps, direct.time_ps);
    assert_eq!(fell_back.events, direct.events);
    assert_eq!(fell_back.traffic, direct.traffic);
}

/// A single-plane 3-D torus is the same machine as the 2-D torus: same
/// node ids, same send schedule, same bytes, same clock — end to end
/// through real payloads.
#[test]
fn single_plane_torus3_matches_the_2d_torus_end_to_end() {
    let mut rng = Pcg32::new(31, 7);
    let p = 12;
    let msgs: Vec<Vec<u8>> = (0..p)
        .map(|_| {
            let len = testkit::usize_in(&mut rng, 0, 200);
            (0..len).map(|_| rng.next_u32() as u8).collect()
        })
        .collect();
    // 2-D torus rows=3, cols=4 lays out id = r·4 + c; the 3-D torus
    // with X=4, Y=3, Z=1 lays out id = y·4 + x — identical grids.
    let t2 = build_topology(TopologyKind::Torus { rows: 3, cols: 4 }, p);
    let t3 = build_topology(TopologyKind::Torus3 { x: 4, y: 3, z: 1 }, p);
    let cfg = FabricConfig::default();
    let mut f2 = Fabric::for_topology(&cfg, &*t2);
    let g2 = t2.allgatherv(&mut f2, &msgs);
    let mut f3 = Fabric::for_topology(&cfg, &*t3);
    let g3 = t3.allgatherv(&mut f3, &msgs);
    assert_eq!(g3.gathered, g2.gathered, "payloads diverged");
    assert_eq!(g3.time_ps, g2.time_ps, "clocks diverged");
    assert_eq!(g3.events, g2.events);
    assert_eq!(g3.traffic, g2.traffic);
}
