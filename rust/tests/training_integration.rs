//! Integration: full training runs over real artifacts, one per codec,
//! plus the cross-cutting coordinator invariants (synchrony, ratio
//! ordering, delayed-update conservation).

use vgc::compress::CodecSpec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::fabric::TopologyKind;
use vgc::optim::LrSchedule;
use vgc::runtime::{Client, Manifest};

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Manifest::load(dir).expect("manifest parses"))
}

fn mlp_cfg(codec: CodecSpec, steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::defaults("mlp");
    cfg.codec = codec;
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.verify_sync = true;
    cfg
}

#[test]
fn every_codec_trains_mlp_to_lower_loss() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let codecs = vec![
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 },
        CodecSpec::Vgc { alpha: 2.0, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.001 },
        CodecSpec::Hybrid { tau: 0.001, alpha: 2.0, zeta: 0.999 },
        CodecSpec::Qsgd { bits: 4, bucket: 128 },
        CodecSpec::TernGrad,
    ];
    for codec in codecs {
        let label = codec.label();
        let mut t = Trainer::new(&client, &man, mlp_cfg(codec, 40)).unwrap();
        t.run(true).unwrap();
        let first = t.metrics.steps.first().unwrap().loss;
        let tail = t.metrics.tail_loss(5);
        assert!(
            tail < first * 0.8,
            "{label}: loss did not fall ({first} -> {tail})"
        );
        assert!(
            tail.is_finite(),
            "{label}: non-finite loss"
        );
    }
}

#[test]
fn sparse_codecs_compress_and_dense_do_not() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();

    let mut none = Trainer::new(&client, &man, mlp_cfg(CodecSpec::None, 25)).unwrap();
    none.run(true).unwrap();
    assert!((none.metrics.compression_ratio() - 1.0).abs() < 1e-9);

    let mut vgc = Trainer::new(
        &client,
        &man,
        mlp_cfg(CodecSpec::Vgc { alpha: 2.0, zeta: 0.999 }, 25),
    )
    .unwrap();
    vgc.run(true).unwrap();
    assert!(
        vgc.metrics.compression_ratio() > 5.0,
        "vgc ratio {} too low",
        vgc.metrics.compression_ratio()
    );
}

#[test]
fn alpha_orders_compression_ratio() {
    // Paper Sec. 4.4: larger α compresses more aggressively.
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let mut ratios = Vec::new();
    for alpha in [1.0f32, 1.5, 2.0] {
        let mut t = Trainer::new(
            &client,
            &man,
            mlp_cfg(CodecSpec::Vgc { alpha, zeta: 0.999 }, 30),
        )
        .unwrap();
        t.run(true).unwrap();
        ratios.push(t.metrics.compression_ratio());
    }
    assert!(
        ratios[0] < ratios[1] && ratios[1] < ratios[2],
        "ratios not increasing with alpha: {ratios:?}"
    );
}

#[test]
fn verify_sync_holds_across_full_run() {
    // verify_sync asserts inside train_step; a desync would panic.
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let mut cfg = mlp_cfg(CodecSpec::Hybrid { tau: 0.001, alpha: 1.0, zeta: 0.999 }, 30);
    cfg.verify_sync = true;
    let mut t = Trainer::new(&client, &man, cfg).unwrap();
    t.run(true).unwrap();
}

#[test]
fn same_seed_reproduces_exactly() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let run = |seed: u64| {
        let mut cfg = mlp_cfg(CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 }, 15);
        cfg.seed = seed;
        let mut t = Trainer::new(&client, &man, cfg).unwrap();
        t.run(true).unwrap();
        (t.params.clone(), t.metrics.compression_ratio())
    };
    let (p1, r1) = run(7);
    let (p2, r2) = run(7);
    assert_eq!(p1, p2, "same seed must give identical parameters");
    assert_eq!(r1, r2);
    let (p3, _) = run(8);
    assert_ne!(p1, p3, "different seed must differ");
}

#[test]
fn adam_runs_after_communication() {
    // Sec. 4.3: Adam preprocessing is local, post-communication — just
    // verify Adam + VGC trains and params stay finite.
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let mut cfg = mlp_cfg(CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 }, 30);
    cfg.optimizer = "adam".into();
    cfg.schedule = LrSchedule::Constant { lr: 0.002 };
    let mut t = Trainer::new(&client, &man, cfg).unwrap();
    t.run(true).unwrap();
    assert!(t.params.iter().all(|p| p.is_finite()));
    let first = t.metrics.steps.first().unwrap().loss;
    assert!(t.metrics.tail_loss(5) < first);
}

#[test]
fn eval_accuracy_improves_with_training() {
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let mut cfg = mlp_cfg(CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 }, 60);
    cfg.eval_every = 30;
    let mut t = Trainer::new(&client, &man, cfg).unwrap();
    let before = t.evaluate().unwrap().accuracy;
    t.run(true).unwrap();
    let after = t.metrics.final_accuracy();
    assert!(
        after > before + 0.3,
        "accuracy {before} -> {after}: no learning"
    );
}

#[test]
fn trainer_comm_phase_honors_configured_topology() {
    // The comm phase runs its allgatherv on the configured fabric: a
    // non-ring topology must change the simulated step time while the
    // training math (identical gathered bytes) stays bit-identical.
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let run = |topology: TopologyKind| {
        let mut cfg = mlp_cfg(CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 }, 8);
        cfg.fabric.topology = topology;
        let mut t = Trainer::new(&client, &man, cfg).unwrap();
        let workers = t.workers();
        t.run(true).unwrap();
        (t.params.clone(), t.sim_comm_ps, workers)
    };
    let (ring_params, ring_ps, workers) = run(TopologyKind::Ring);
    if workers < 2 {
        eprintln!("SKIP: single-worker model has no comm phase");
        return;
    }
    for topology in [TopologyKind::Star, TopologyKind::Hier { groups: 2 }] {
        let (params, sim_ps, _) = run(topology);
        assert_eq!(
            ring_params, params,
            "{topology:?}: topology changed the training math"
        );
        assert!(ring_ps > 0 && sim_ps > 0);
        assert_ne!(
            ring_ps, sim_ps,
            "{topology:?}: simulated comm time ignored the topology"
        );
    }
}

#[test]
fn residual_conservation_under_training() {
    // VGC invariant over a real gradient stream: residual mass is
    // finite and bounded; after a send, state resets (checked
    // statistically: the residual L1 must not blow up monotonically).
    let Some(man) = manifest() else { return };
    let client = Client::cpu().unwrap();
    let mut t = Trainer::new(
        &client,
        &man,
        mlp_cfg(CodecSpec::Vgc { alpha: 1.0, zeta: 0.999 }, 50),
    )
    .unwrap();
    let mut l1s = Vec::new();
    for _ in 0..50 {
        t.train_step().unwrap();
        l1s.push(t.residual_l1());
    }
    let max = l1s.iter().cloned().fold(0.0f64, f64::max);
    assert!(max.is_finite() && max > 0.0);
    // Late-run residual should not be orders of magnitude above the
    // running maximum of the first half (no runaway accumulation).
    let first_half_max = l1s[..25].iter().cloned().fold(0.0f64, f64::max);
    assert!(
        *l1s.last().unwrap() < first_half_max * 20.0,
        "runaway residual: {l1s:?}"
    );
}
