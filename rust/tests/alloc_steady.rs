//! The §Perf zero-allocation contract: once buffer capacities converge,
//! the sparse codec kernels (`encode_step_into` + `decode_entries`)
//! perform **zero** heap allocations per step. This test installs the
//! counting allocator for its own test binary and measures deltas
//! around steady-state steps.
//!
//! Scope: the paper codecs and their wire path (vgc, vgc-γ, strom,
//! hybrid, adaptive, none). The stochastic dense baselines (qsgd,
//! terngrad, onebit) reuse their encode scratch too but their decode
//! goes through the dense fallback, which is exercised for the `none`
//! codec here.

use vgc::compress::{Codec, CodecSpec, DecodeBuf};
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::alloc::{allocations, CountingAlloc};
use vgc::util::rng::Pcg32;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc::new();

fn steady_state_allocs(spec: &CodecSpec) -> u64 {
    let n = 20_000;
    let layout = Layout::uniform(n, 256);
    let mut codec = spec.build(&layout, 0);
    let mut rng = Pcg32::new(7, 7);
    let g = testkit::gradient_vec(&mut rng, n);
    let q: Vec<f32> = g.iter().map(|x| x * x * 0.9).collect();
    let mut bytes = Vec::new();
    let mut buf = DecodeBuf::new();
    let mut sink = 0u64;

    let mut one_step = |codec: &mut Box<dyn Codec>,
                        bytes: &mut Vec<u8>,
                        buf: &mut DecodeBuf,
                        sink: &mut u64| {
        let st = codec.encode_step_into(&g, &q, bytes);
        *sink ^= st.elements;
        buf.reset(n);
        codec.decode_entries(bytes, buf).unwrap();
        *sink ^= buf.len() as u64;
    };

    // Warm up: residual state cycles and every scratch capacity reaches
    // its peak within a few steps on a fixed input stream.
    for _ in 0..8 {
        one_step(&mut codec, &mut bytes, &mut buf, &mut sink);
    }
    // Measure: the minimum delta over several steps (a converged step
    // must allocate nothing).
    let mut min_delta = u64::MAX;
    for _ in 0..4 {
        let before = allocations();
        one_step(&mut codec, &mut bytes, &mut buf, &mut sink);
        min_delta = min_delta.min(allocations() - before);
    }
    std::hint::black_box(sink);
    min_delta
}

#[test]
fn steady_state_wire_path_allocates_nothing() {
    for spec in [
        CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
        CodecSpec::VgcCompact { alpha: 1.5, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.01 },
        CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 },
        CodecSpec::Adaptive { pi: 0.02 },
        CodecSpec::None,
    ] {
        let allocs = steady_state_allocs(&spec);
        assert_eq!(
            allocs,
            0,
            "codec {} allocated {allocs} times in a steady-state step",
            spec.label()
        );
    }
}
