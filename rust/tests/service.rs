//! End-to-end tests for the training service daemon: boot `repro serve`
//! on an ephemeral port, drive it purely over HTTP, and check that
//!
//! - concurrent jobs sharing one daemon produce summaries bit-identical
//!   to one-shot CLI runs of the same specs,
//! - per-queue concurrency limits hold under load,
//! - failed jobs retry with exponentially increasing backoff,
//! - cancellation takes queued jobs instantly and running jobs at the
//!   next step boundary,
//! - SIGTERM drains in-flight work and persists a terminal snapshot,
//! - a train job with a fault plan forwards `fault` / `degraded`
//!   NDJSON events and a `fault_report` summary (needs artifacts),
//! - an `--adaptive` train job on a comm-bound hierarchy forwards the
//!   controller's `knob` NDJSON events (needs artifacts).

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use vgc::experiments::{fabric_sweep, fabric_sweep_json, FabricSweepOpts};
use vgc::service::http::{http_request, http_stream};
use vgc::util::json::Json;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

/// Unique scratch path per test (tests share one process; names must
/// not collide across parallel test threads).
fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("vgc_service_{}_{tag}.json", std::process::id()))
}

/// `j[key]` as a string, panicking with context on absence.
fn sget<'a>(j: &'a Json, key: &str) -> &'a str {
    j.get(key).unwrap_or_else(|| panic!("no key '{key}'")).as_str().unwrap()
}

/// `j[key]` as an unsigned number.
fn nget(j: &Json, key: &str) -> u64 {
    j.get(key).unwrap_or_else(|| panic!("no key '{key}'")).as_usize().unwrap() as u64
}

fn is_terminal(state: &str) -> bool {
    matches!(state, "succeeded" | "failed" | "cancelled")
}

/// True when the NDJSON line's `event` field equals `want`.
fn event_is(e: &Json, want: &str) -> bool {
    e.get("event").and_then(|v| v.as_str().ok()) == Some(want)
}

/// A `repro serve` child on an ephemeral port. Stdout is consumed by a
/// drain thread after the listen line so the child never blocks on a
/// full pipe; the process is killed on drop if a test panics early.
struct DaemonProc {
    child: Child,
    addr: String,
}

impl DaemonProc {
    fn spawn(extra: &[&str]) -> DaemonProc {
        let mut cmd = repro();
        cmd.args(["serve", "--listen", "127.0.0.1:0"])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::null());
        let mut child = cmd.spawn().expect("spawn repro serve");
        let stdout = child.stdout.take().expect("daemon stdout");
        let mut reader = BufReader::new(stdout);
        let mut addr = None;
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap_or(0) > 0 {
            if let Some(rest) = line.trim().strip_prefix("serve: listening on ") {
                addr = Some(rest.to_string());
                break;
            }
            line.clear();
        }
        let addr = addr.expect("daemon never announced its listen address");
        std::thread::spawn(move || {
            let mut sink = String::new();
            loop {
                sink.clear();
                if reader.read_line(&mut sink).unwrap_or(0) == 0 {
                    break;
                }
            }
        });
        DaemonProc { child, addr }
    }

    /// POST /shutdown, wait for exit, and assert a clean drain.
    fn shutdown(mut self) {
        let (code, _) = http_request(&self.addr, "POST", "/shutdown", None).expect("shutdown");
        assert_eq!(code, 200);
        let status = self.child.wait().expect("wait for daemon exit");
        assert!(status.success(), "daemon exited uncleanly: {status:?}");
    }
}

impl Drop for DaemonProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// POST a job envelope; return the assigned job id.
fn submit(addr: &str, envelope: &str) -> u64 {
    let (code, body) = http_request(addr, "POST", "/jobs", Some(envelope)).expect("POST /jobs");
    assert_eq!(code, 200, "submit rejected: {body}");
    nget(&Json::parse(&body).expect("submit response json"), "job")
}

fn get_job(addr: &str, id: u64) -> Json {
    let (code, body) = http_request(addr, "GET", &format!("/jobs/{id}"), None).unwrap();
    assert_eq!(code, 200, "job {id} lookup failed: {body}");
    Json::parse(&body).expect("job snapshot json")
}

/// Poll `GET /jobs/:id` until the job reaches a terminal state.
fn wait_terminal(addr: &str, id: u64, timeout: Duration) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let snap = get_job(addr, id);
        if is_terminal(sget(&snap, "state")) {
            return snap;
        }
        assert!(Instant::now() < deadline, "job {id} not terminal after {timeout:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Poll `GET /jobs/:id` until the job is observed `running`.
fn wait_running(addr: &str, id: u64, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    loop {
        let state = sget(&get_job(addr, id), "state").to_string();
        if state == "running" {
            return;
        }
        assert!(!is_terminal(&state), "job {id} terminal '{state}' before it was seen running");
        assert!(Instant::now() < deadline, "job {id} never started");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Stream `GET /jobs/:id/events` to completion, parsing each NDJSON
/// line. The server closes the stream after the job's terminal event.
fn stream_to_end(addr: &str, id: u64) -> Vec<Json> {
    let mut events = Vec::new();
    let code = http_stream(addr, &format!("/jobs/{id}/events"), &mut |line| {
        events.push(Json::parse(line).expect("event line json"));
    })
    .expect("stream events");
    assert_eq!(code, 200);
    events
}

/// The sweep spec used for the bit-identity check: the daemon job, the
/// in-process run, and the CLI flags below all describe this spec, so
/// any divergence between the three paths is the code's, not the test's.
const SWEEP_SPEC: &str = concat!(
    r#"{"topologies":"ring,star","workers":[3,4],"bandwidths_gbps":[1.0],"#,
    r#""codecs":["none","vgc:alpha=2"],"n_params":4096}"#,
);

#[test]
fn concurrent_http_jobs_match_one_shot_runs_bit_for_bit() {
    let state = scratch("concurrent");
    let _ = std::fs::remove_file(&state);
    let state_flag = state.to_str().unwrap().to_string();
    let d = DaemonProc::spawn(&["--queues", "sweeps=2,bench=2", "--state", &state_flag]);

    let (code, body) = http_request(&d.addr, "GET", "/healthz", None).unwrap();
    assert_eq!(code, 200);
    let health = Json::parse(&body).unwrap();
    assert_eq!(sget(&health, "status"), "ok");
    assert!(nget(&health, "engine_threads") >= 1);

    // Two jobs in flight at once, on different queues, sharing the
    // daemon's codec engine and fabric model.
    let sweep_env =
        format!(r#"{{"job":"fabric-sweep","name":"s","queue":"sweeps","spec":{SWEEP_SPEC}}}"#);
    const BENCH_ENV: &str = concat!(
        r#"{"job":"bench-codecs","name":"b","queue":"bench","spec":"#,
        r#"{"n":4096,"group":256,"workers":2,"threads":[1],"alloc_steps":1,"#,
        r#""codecs":["vgc:alpha=1.5","strom:tau=0.01"]}}"#,
    );
    let sweep_id = submit(&d.addr, &sweep_env);
    let bench_id = submit(&d.addr, BENCH_ENV);

    // Stream the sweep's events while it runs; the server ends the
    // stream at the job's terminal event.
    let addr = d.addr.clone();
    let streamer = std::thread::spawn(move || stream_to_end(&addr, sweep_id));

    let sweep = wait_terminal(&d.addr, sweep_id, Duration::from_secs(120));
    let bench = wait_terminal(&d.addr, bench_id, Duration::from_secs(120));
    assert_eq!(sget(&sweep, "state"), "succeeded", "sweep: {:?}", sweep.get("error"));
    assert_eq!(sget(&bench, "state"), "succeeded", "bench: {:?}", bench.get("error"));

    let events = streamer.join().expect("event stream thread");
    let kinds: Vec<&str> = events.iter().map(|e| sget(e, "event")).collect();
    assert!(kinds.contains(&"queued"), "missing queued event: {kinds:?}");
    assert!(kinds.contains(&"started"), "missing started event: {kinds:?}");
    assert!(kinds.contains(&"progress"), "missing progress event: {kinds:?}");
    let last = events.last().expect("stream delivered no events");
    assert_eq!(sget(last, "event"), "finished");
    assert_eq!(sget(last, "state"), "succeeded");

    // Bit-identity #1: daemon sweep rows vs an in-process one-shot run
    // of the identical spec.
    let opts = FabricSweepOpts::from_json(&Json::parse(SWEEP_SPEC).unwrap()).unwrap();
    let expected = fabric_sweep_json(&fabric_sweep(&opts)).to_string();
    let result = sweep.get("result").expect("sweep result");
    let daemon_rows = result.get("rows").expect("result rows").to_string();
    assert_eq!(daemon_rows, expected, "daemon sweep diverged from one-shot");

    // Bit-identity #2: vs the one-shot CLI's --out file.
    let out = scratch("cli_sweep");
    let mut cli = repro();
    cli.args(["fabric-sweep", "--topologies", "ring,star", "--workers", "3,4"])
        .args(["--bandwidth-gbps", "1", "--codecs", "none+vgc:alpha=2", "--n", "4096"])
        .args(["--out", out.to_str().unwrap()]);
    let cli = cli.output().expect("run one-shot fabric-sweep");
    assert!(cli.status.success(), "{}", String::from_utf8_lossy(&cli.stderr));
    let file = std::fs::read_to_string(&out).expect("read CLI --out file");
    assert_eq!(file.trim_end(), expected, "daemon sweep diverged from the CLI");
    let _ = std::fs::remove_file(&out);

    // Bench summary sanity (timing fields are measurements; the full
    // deterministic-field equality lives in the service unit tests).
    let report = bench.get("result").expect("bench result");
    assert_eq!(sget(report, "kind"), "bench-codecs");
    let inner = report.get("report").expect("bench report");
    let rows = inner.get("rows").expect("bench rows").as_arr().unwrap();
    assert_eq!(rows.len(), 2, "one bench row per codec");

    // Control-plane reads.
    let (code, body) = http_request(&d.addr, "GET", "/queues", None).unwrap();
    assert_eq!(code, 200);
    let queues = Json::parse(&body).unwrap();
    let arr = queues.as_arr().unwrap();
    let sweeps_q = arr.iter().find(|q| sget(q, "name") == "sweeps").expect("sweeps queue");
    assert_eq!(nget(sweeps_q, "max_concurrent"), 2);

    let (code, body) = http_request(&d.addr, "GET", "/fabric", None).unwrap();
    assert_eq!(code, 200);
    let fabric = Json::parse(&body).unwrap();
    assert!(nget(fabric.get("usage").unwrap(), "jobs") >= 1);

    // Error paths.
    let (code, _) = http_request(&d.addr, "GET", "/jobs/999999", None).unwrap();
    assert_eq!(code, 404);
    let (code, _) = http_request(&d.addr, "POST", "/jobs", Some("{not json")).unwrap();
    assert_eq!(code, 400);

    d.shutdown();
    let snap = Json::parse(&std::fs::read_to_string(&state).expect("state file")).unwrap();
    for job in snap.get("jobs").unwrap().as_arr().unwrap() {
        let st = sget(job, "state");
        assert!(is_terminal(st), "non-terminal state '{st}' persisted");
    }
    let _ = std::fs::remove_file(&state);
}

#[test]
fn per_queue_concurrency_limit_holds_under_load() {
    let d = DaemonProc::spawn(&["--queues", "solo=1", "--sched-threads", "4"]);
    const SPEC: &str = concat!(
        r#"{"job":"fabric-sweep","queue":"solo","spec":"#,
        r#"{"topologies":"ring","workers":[6],"bandwidths_gbps":[1.0],"#,
        r#""codecs":["none","vgc:alpha=2"],"n_params":65536}}"#,
    );
    let ids: Vec<u64> = (0..3).map(|_| submit(&d.addr, SPEC)).collect();

    // Sample the queue while the jobs flow through it: the `solo` queue
    // must never report more than its limit running. Sampling cannot
    // falsely fail — every observation is a real scheduler state.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut max_running = 0;
    loop {
        let (code, body) = http_request(&d.addr, "GET", "/queues", None).unwrap();
        assert_eq!(code, 200);
        let queues = Json::parse(&body).unwrap();
        let arr = queues.as_arr().unwrap();
        let solo = arr.iter().find(|q| sget(q, "name") == "solo").expect("solo queue");
        let running = nget(solo, "running");
        assert!(running <= 1, "solo queue ran {running} jobs at once");
        max_running = max_running.max(running);

        let (_, body) = http_request(&d.addr, "GET", "/jobs", None).unwrap();
        let jobs = Json::parse(&body).unwrap();
        let arr = jobs.as_arr().unwrap();
        let done = arr.iter().filter(|j| is_terminal(sget(j, "state"))).count();
        if done == ids.len() {
            break;
        }
        assert!(Instant::now() < deadline, "jobs did not finish in time");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(max_running >= 1, "sampler never saw a job running");
    for id in ids {
        assert_eq!(sget(&get_job(&d.addr, id), "state"), "succeeded");
    }
    d.shutdown();
}

#[test]
fn failed_jobs_retry_with_exponential_backoff() {
    let flags = ["--retry-base-ms", "40", "--retry-factor", "2", "--retry-max-ms", "1000"];
    let d = DaemonProc::spawn(&flags);
    // n_params = 0 passes spec parsing but fails sweep validation at
    // execution time, so every attempt fails.
    let env = r#"{"job":"fabric-sweep","max_retries":2,"spec":{"n_params":0}}"#;
    let id = submit(&d.addr, env);
    let events = stream_to_end(&d.addr, id);
    let delays: Vec<u64> = events
        .iter()
        .filter(|e| sget(e, "event") == "retry")
        .map(|e| nget(e, "delay_ms"))
        .collect();
    assert_eq!(delays, vec![40, 80], "retry delays must grow base·factor^k");

    let snap = wait_terminal(&d.addr, id, Duration::from_secs(30));
    assert_eq!(sget(&snap, "state"), "failed");
    assert_eq!(nget(&snap, "attempts"), 3);
    assert!(sget(&snap, "error").contains("n_params"));
    d.shutdown();
}

#[test]
fn cancel_takes_queued_jobs_instantly_and_running_jobs_at_a_step_boundary() {
    let d = DaemonProc::spawn(&["--queues", "default=1"]);
    // Heavy enough that a cancel issued the moment the job is seen
    // running lands well before its first worker-count cell completes.
    const HEAVY: &str = concat!(
        r#"{"job":"fabric-sweep","name":"heavy","spec":"#,
        r#"{"topologies":"ring","workers":[4,5,6],"bandwidths_gbps":[1.0],"#,
        r#""codecs":["none","vgc:alpha=2"],"n_params":2000000}}"#,
    );
    const LIGHT: &str = concat!(
        r#"{"job":"fabric-sweep","name":"light","spec":"#,
        r#"{"topologies":"ring","workers":[4],"bandwidths_gbps":[1.0],"#,
        r#""codecs":["none"],"n_params":4096}}"#,
    );
    let running_id = submit(&d.addr, HEAVY);
    let queued_id = submit(&d.addr, LIGHT);
    wait_running(&d.addr, running_id, Duration::from_secs(30));

    // The queued job (parked behind the heavy one on a width-1 queue)
    // cancels immediately, without ever starting.
    let path = format!("/jobs/{queued_id}/cancel");
    let (code, body) = http_request(&d.addr, "POST", &path, None).unwrap();
    assert_eq!(code, 200, "cancel rejected: {body}");
    assert_eq!(sget(&Json::parse(&body).unwrap(), "state"), "cancelled");
    let snap = wait_terminal(&d.addr, queued_id, Duration::from_secs(10));
    assert_eq!(nget(&snap, "attempts"), 0, "cancelled job must never have started");

    // The running job stops cooperatively at its next cell boundary.
    let path = format!("/jobs/{running_id}/cancel");
    let (code, _) = http_request(&d.addr, "POST", &path, None).unwrap();
    assert_eq!(code, 200);
    let snap = wait_terminal(&d.addr, running_id, Duration::from_secs(120));
    assert_eq!(sget(&snap, "state"), "cancelled");
    d.shutdown();
}

#[cfg(unix)]
#[test]
fn sigterm_drains_in_flight_work_and_persists_state() {
    let state = scratch("sigterm");
    let _ = std::fs::remove_file(&state);
    let state_flag = state.to_str().unwrap().to_string();
    let mut d = DaemonProc::spawn(&["--queues", "default=1", "--state", &state_flag]);
    const BUSY: &str = concat!(
        r#"{"job":"fabric-sweep","name":"busy","spec":"#,
        r#"{"topologies":"ring","workers":[4,5],"bandwidths_gbps":[1.0],"#,
        r#""codecs":["none","vgc:alpha=2"],"n_params":500000}}"#,
    );
    const LIGHT: &str = concat!(
        r#"{"job":"fabric-sweep","name":"light","spec":"#,
        r#"{"topologies":"ring","workers":[4],"bandwidths_gbps":[1.0],"#,
        r#""codecs":["none"],"n_params":4096}}"#,
    );
    let busy_id = submit(&d.addr, BUSY);
    let light_id = submit(&d.addr, LIGHT);
    wait_running(&d.addr, busy_id, Duration::from_secs(30));

    let pid = d.child.id().to_string();
    let kill = Command::new("kill").args(["-TERM", &pid]).status().expect("send SIGTERM");
    assert!(kill.success());
    let status = d.child.wait().expect("wait after SIGTERM");
    assert!(status.success(), "SIGTERM drain exited uncleanly: {status:?}");

    // Drain semantics: the in-flight job finished; the queued one was
    // cancelled before it could start. Both are terminal on disk.
    let snap = Json::parse(&std::fs::read_to_string(&state).expect("state file")).unwrap();
    let jobs = snap.get("jobs").unwrap().as_arr().unwrap();
    let state_of = |id: u64| {
        let job = jobs.iter().find(|j| nget(j, "id") == id).expect("job in snapshot");
        sget(job, "state")
    };
    assert_eq!(state_of(busy_id), "succeeded", "in-flight job not drained to completion");
    assert_eq!(state_of(light_id), "cancelled", "queued job not cancelled by the drain");
    let _ = std::fs::remove_file(&state);
}

#[test]
fn train_job_over_http_matches_in_process_run() {
    if !have_artifacts() {
        eprintln!("skipping: no compiled artifacts (run tools/compile_models.py)");
        return;
    }
    let client = match vgc::runtime::Client::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: no CPU client: {e:#}");
            return;
        }
    };

    let mut cfg = vgc::config::TrainConfig::defaults("mlp");
    cfg.codec = vgc::compress::CodecSpec::parse("vgc:alpha=1.5").unwrap();
    cfg.steps = 5;
    cfg.codec_threads = 1;
    // Run through the bucketed overlap pipeline: the daemon path must
    // stay bit-identical to the in-process run with it on, too.
    cfg.bucket_bytes = 4096;
    cfg.overlap = true;
    let spec = cfg.to_json().to_string();

    let d = DaemonProc::spawn(&["--codec-threads", "1"]);
    let id = submit(&d.addr, &format!(r#"{{"job":"train","spec":{spec}}}"#));
    let snap = wait_terminal(&d.addr, id, Duration::from_secs(300));
    assert_eq!(sget(&snap, "state"), "succeeded", "train: {:?}", snap.get("error"));
    let result = snap.get("result").expect("train result");

    // Live telemetry: one `step` NDJSON event per training step, each
    // carrying loss, the cumulative compression ratio, and the
    // simulated (overlapped) step span. The bus replays a terminal
    // job's history, so streaming after completion sees all of them.
    let events = stream_to_end(&d.addr, id);
    d.shutdown();
    let steps: Vec<&Json> = events.iter().filter(|e| event_is(e, "step")).collect();
    assert_eq!(steps.len() as u64, cfg.steps, "one step event per training step");
    for (i, e) in steps.iter().enumerate() {
        assert_eq!(nget(e, "step"), i as u64 + 1, "step events in order");
        assert!(e.get("loss").unwrap().as_f64().unwrap().is_finite());
        assert!(e.get("comp_ratio").unwrap().as_f64().unwrap() > 1.0, "vgc must compress");
        assert!(nget(e, "sim_step_ps") > 0, "step span must be simulated");
    }

    let manifest = vgc::runtime::Manifest::load("artifacts").unwrap();
    let mut trainer = vgc::coordinator::Trainer::new(&client, &manifest, cfg).unwrap();
    trainer.run(true).unwrap();
    let fnv = format!("{:016x}", vgc::service::fnv64_f32(&trainer.params));
    assert_eq!(sget(&result, "params_fnv64"), fnv, "daemon train diverged from in-process");
    assert_eq!(nget(&result, "steps"), trainer.step_count());
}

#[test]
fn adaptive_train_job_streams_knob_events() {
    if !have_artifacts() {
        eprintln!("skipping: no compiled artifacts (run tools/compile_models.py)");
        return;
    }
    let client = match vgc::runtime::Client::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: no CPU client: {e:#}");
            return;
        }
    };

    // Comm-bound on purpose: a 5 Mbps inter-rack uplink dwarfs any
    // measured compute time, so the closed-loop controller must tighten
    // and the daemon must forward its moves as `knob` NDJSON events.
    let mut cfg = vgc::config::TrainConfig::defaults("mlp");
    cfg.codec = vgc::compress::CodecSpec::parse("vgc:alpha=0.5").unwrap();
    cfg.steps = 6;
    cfg.codec_threads = 1;
    cfg.adaptive = true;
    cfg.fabric.topology = vgc::fabric::TopologyKind::Hier { groups: 2 };
    cfg.fabric.inter_rack_gbps = Some(0.005);

    // The hierarchy needs a second worker; probe the model's
    // parallelism in-process before spending a daemon boot.
    let manifest = vgc::runtime::Manifest::load("artifacts").unwrap();
    let probe = vgc::coordinator::Trainer::new(&client, &manifest, cfg.clone()).unwrap();
    if probe.workers() < 2 {
        eprintln!("skipping: single-worker model has no fabric to adapt to");
        return;
    }

    let spec = cfg.to_json().to_string();
    let d = DaemonProc::spawn(&["--codec-threads", "1"]);
    let id = submit(&d.addr, &format!(r#"{{"job":"train","spec":{spec}}}"#));
    let snap = wait_terminal(&d.addr, id, Duration::from_secs(300));
    assert_eq!(sget(&snap, "state"), "succeeded", "train: {:?}", snap.get("error"));

    // The bus replays a terminal job's history, so streaming after
    // completion still sees every knob event.
    let events = stream_to_end(&d.addr, id);
    d.shutdown();

    let knobs: Vec<&Json> = events.iter().filter(|e| event_is(e, "knob")).collect();
    assert!(!knobs.is_empty(), "comm-bound adaptive run emitted no knob events");
    for e in &knobs {
        assert_eq!(sget(e, "name"), "zeta", "vgc's knob is the variance decay");
        let step = nget(e, "step");
        assert!((1..=cfg.steps).contains(&step), "knob step {step} out of range");
        let v = e.get("value").unwrap().as_f64().unwrap();
        assert!(v > 0.0 && v <= 1.0, "zeta out of range: {v}");
        assert!(e.get("gain").unwrap().as_f64().unwrap().is_finite());
        let _bucket = nget(e, "bucket"); // present and unsigned
    }
}

#[test]
fn train_job_streams_fault_and_degraded_events() {
    if !have_artifacts() {
        eprintln!("skipping: no compiled artifacts (run tools/compile_models.py)");
        return;
    }
    let client = match vgc::runtime::Client::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: no CPU client: {e:#}");
            return;
        }
    };

    let mut cfg = vgc::config::TrainConfig::defaults("mlp");
    cfg.codec = vgc::compress::CodecSpec::parse("vgc:alpha=1.5").unwrap();
    cfg.steps = 8;
    cfg.codec_threads = 1;
    cfg.fabric.faults = vgc::fabric::FaultPlan::parse("crash:1@3+2").unwrap();

    // The crash scenario needs a second worker to lose; probe the
    // model's parallelism in-process before spending a daemon boot.
    let manifest = vgc::runtime::Manifest::load("artifacts").unwrap();
    let probe = vgc::coordinator::Trainer::new(&client, &manifest, cfg.clone()).unwrap();
    if probe.workers() < 2 {
        eprintln!("skipping: single-worker model has no membership to degrade");
        return;
    }
    let total = probe.workers() as u64;

    let spec = cfg.to_json().to_string();
    let d = DaemonProc::spawn(&["--codec-threads", "1"]);
    let id = submit(&d.addr, &format!(r#"{{"job":"train","spec":{spec}}}"#));
    let snap = wait_terminal(&d.addr, id, Duration::from_secs(300));
    assert_eq!(sget(&snap, "state"), "succeeded", "train: {:?}", snap.get("error"));

    // The bus replays a terminal job's full history, so streaming
    // after completion still sees every fault event in order.
    let events = stream_to_end(&d.addr, id);
    d.shutdown();

    let faults: Vec<(u64, String, u64)> = events
        .iter()
        .filter(|e| event_is(e, "fault"))
        .map(|e| (nget(e, "step"), sget(e, "kind").to_string(), nget(e, "node")))
        .collect();
    assert_eq!(
        faults,
        vec![(3, "crash".to_string(), 1), (5, "rejoin".to_string(), 1)],
        "fault NDJSON events must mirror the plan"
    );
    let degraded: Vec<(u64, u64, u64)> = events
        .iter()
        .filter(|e| event_is(e, "degraded"))
        .map(|e| (nget(e, "step"), nget(e, "live"), nget(e, "total")))
        .collect();
    assert_eq!(degraded, vec![(3, total - 1, total), (4, total - 1, total)]);

    let result = snap.get("result").expect("train result");
    let report = result.get("fault_report").expect("summary fault_report");
    assert!(nget(report, "reroutes") > 0, "degraded gathers must be counted as reroutes");
}
