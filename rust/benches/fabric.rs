//! Fabric bench: raw event throughput of the discrete-event engine,
//! and the 16-worker ring vs parameter-server allgatherv step (both
//! the host cost of simulating it and the simulated wall-clock it
//! predicts).

use vgc::bench::Bencher;
use vgc::fabric::{build_topology, Fabric, FabricConfig, LinkSpec, TopologyKind};
use vgc::util::rng::Pcg32;

fn messages(p: usize, bytes: usize) -> Vec<Vec<u8>> {
    (0..p)
        .map(|w| {
            let mut rng = Pcg32::new(w as u64, 3);
            (0..bytes).map(|_| rng.next_u32() as u8).collect()
        })
        .collect()
}

fn config() -> FabricConfig {
    FabricConfig {
        link: LinkSpec::gige(),
        ..FabricConfig::default()
    }
}

fn main() {
    let b = Bencher::default();
    let p = 16;

    // Engine event throughput: a tree gatherv at branch 4 exercises
    // fan-in, fan-out and forwarding; tiny payloads isolate the
    // scheduler cost from byte shuffling.
    let tiny = messages(p, 64);
    let kind = TopologyKind::Tree { branch: 4 };
    let topo = build_topology(kind, p);
    let events_per_run = {
        let mut f = Fabric::for_config(&config(), topo.node_count());
        topo.allgatherv(&mut f, &tiny).events
    };
    b.report_throughput(
        &format!("fabric/events/tree4/p={p}"),
        events_per_run as f64,
        "ev",
        || {
            let mut f = Fabric::for_config(&config(), topo.node_count());
            let r = topo.allgatherv(&mut f, &tiny);
            std::hint::black_box(r.time_ps);
        },
    );

    // Ring vs parameter-server at a codec-realistic 64 KiB message.
    let msgs = messages(p, 64 * 1024);
    for kind in [TopologyKind::Ring, TopologyKind::Star] {
        let topo = build_topology(kind, p);
        let mut probe = Fabric::for_config(&config(), topo.node_count());
        let sim = topo.allgatherv(&mut probe, &msgs);
        println!(
            "sim   {:<44} step={:.3} ms  traffic={} B  max_link={} B  events={}",
            format!("fabric/allgatherv/{}/p={p}/64KiB", kind.label()),
            sim.time_secs() * 1e3,
            sim.traffic.total_bytes(),
            probe.max_link_bytes(),
            sim.events,
        );
        b.report_throughput(
            &format!("fabric/allgatherv/{}/p={p}/64KiB", kind.label()),
            sim.events as f64,
            "ev",
            || {
                let mut f = Fabric::for_config(&config(), topo.node_count());
                let r = topo.allgatherv(&mut f, &msgs);
                std::hint::black_box(r.time_ps);
            },
        );
    }
}
