//! Runtime + end-to-end benches over real artifacts:
//!
//! * the L1 fused moments kernel through PJRT (the paper's 2N|B| madds);
//! * the Eq.-3 criterion: native Rust loop vs the XLA-offload artifact
//!   (the DESIGN.md ablation);
//! * one full coordinated training step per table workload — the
//!   end-to-end rows for Tables 1 and 2 in EXPERIMENTS.md §Perf.

use vgc::bench::Bencher;
use vgc::compress::vgc::VgcCodec;
use vgc::config::TrainConfig;
use vgc::coordinator::Trainer;
use vgc::runtime::{literal_f32, Client, Manifest};
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP runtime bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let man = Manifest::load(&dir)?;
    let client = Client::cpu()?;
    let b = Bencher::default();

    // L1 moments kernel through PJRT.
    for e in &man.moments_bench {
        let exe = client.load_hlo(man.path_of(&e.hlo))?;
        let mut rng = Pcg32::new(3, 3);
        let g: Vec<f32> = (0..e.b * e.n).map(|_| rng.next_normal()).collect();
        let lit = literal_f32(&g, &[e.b as i64, e.n as i64])?;
        b.report_throughput(
            &format!("pjrt/moments b={} n={}", e.b, e.n),
            (e.b * e.n) as f64,
            "elem",
            || {
                let out = exe.execute(&[lit.clone()]).unwrap();
                std::hint::black_box(out.len());
            },
        );
    }

    // Criterion: native loop vs XLA artifact (ablation).
    for e in &man.criterion {
        let n = e.n;
        let mut rng = Pcg32::new(5, 5);
        let r = testkit::gradient_vec(&mut rng, n);
        let v: Vec<f32> = r.iter().map(|x| x * x * 1.2).collect();
        b.report_throughput(&format!("criterion/native n={n}"), n as f64, "elem", || {
            let mut sent = 0u32;
            for i in 0..n {
                sent += VgcCodec::criterion(r[i], v[i], 1.5) as u32;
            }
            std::hint::black_box(sent);
        });
        let exe = client.load_hlo(man.path_of(&e.hlo))?;
        let r_lit = literal_f32(&r, &[n as i64])?;
        let v_lit = literal_f32(&v, &[n as i64])?;
        let a_lit = xla::Literal::scalar(1.5f32);
        b.report_throughput(&format!("criterion/xla n={n}"), n as f64, "elem", || {
            let out = exe
                .execute(&[r_lit.clone(), v_lit.clone(), a_lit.clone()])
                .unwrap();
            std::hint::black_box(out.len());
        });
    }

    // End-to-end steps: one bench per paper table's workload.
    for (table, model) in [("table1", "vgg_tiny"), ("table2", "resnet_mini")] {
        let mut cfg = TrainConfig::defaults(model);
        cfg.codec = vgc::compress::CodecSpec::Vgc {
            alpha: 1.5,
            zeta: 0.999,
        };
        cfg.eval_every = 0;
        cfg.log_every = 0;
        let mut t = Trainer::new(&client, &man, cfg)?;
        t.train_step()?; // warm the executable
        b.report(&format!("e2e/{table} step ({model})"), || {
            t.train_step().unwrap();
        });
        let ph = t.phases;
        let total = ph.compute_s + ph.encode_s + ph.comm_decode_s + ph.update_s;
        println!(
            "  phase split: compute {:.1}% encode {:.1}% comm+decode {:.1}% update {:.1}%",
            ph.compute_s / total * 100.0,
            ph.encode_s / total * 100.0,
            ph.comm_decode_s / total * 100.0,
            ph.update_s / total * 100.0
        );
    }
    Ok(())
}
