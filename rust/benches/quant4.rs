//! Quantizer micro-bench: the Sec.-4.4 bit-twiddled 4-bit encode and
//! decode, plus the Eq.-3 criterion sweep — the innermost loops of the
//! VGC hot path.

use vgc::bench::Bencher;
use vgc::compress::quant4;
use vgc::compress::vgc::VgcCodec;
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn main() {
    let b = Bencher::default();
    let n = 1_000_000usize;
    let mut rng = Pcg32::new(1, 1);
    let g = testkit::gradient_vec(&mut rng, n);
    let m = g.iter().fold(0f32, |a, x| a.max(x.abs()));
    let mexp = quant4::floor_log2_exp(m);

    b.report_throughput("quant4/encode", n as f64, "elem", || {
        let mut kept = 0u32;
        for &x in &g {
            if let Some((neg, d)) = quant4::quantize(x, mexp) {
                kept += (neg as u32) + d as u32;
            }
        }
        std::hint::black_box(kept);
    });

    b.report_throughput("quant4/decode", n as f64, "elem", || {
        let mut acc = 0f32;
        for i in 0..n {
            acc += quant4::dequantize(i & 1 == 0, (i % 8) as u8, mexp);
        }
        std::hint::black_box(acc);
    });

    // The Eq.-3 send decision over accumulated state (branch-heavy).
    let r: Vec<f32> = g.clone();
    let v: Vec<f32> = g.iter().map(|x| x * x * 1.3).collect();
    b.report_throughput("criterion/native", n as f64, "elem", || {
        let mut sent = 0u32;
        for i in 0..n {
            if VgcCodec::criterion(r[i], v[i], 1.5) {
                sent += 1;
            }
        }
        std::hint::black_box(sent);
    });
}
