//! Table-1/Table-2 bench: a short-horizon run of every paper codec row
//! on both workloads, printing the same columns the paper reports
//! (accuracy is meaningless at this horizon — the full-horizon runs
//! live in `repro table1`/`table2`; this bench tracks the *ratio*
//! ordering and per-row step cost so regressions show up in
//! `cargo bench`).

use vgc::bench::Bencher;
use vgc::coordinator::Trainer;
use vgc::experiments;
use vgc::runtime::{Client, Manifest};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP tables bench: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let man = Manifest::load(&dir)?;
    let client = Client::cpu()?;
    let b = Bencher {
        min_iters: 3,
        budget: std::time::Duration::from_millis(1),
        warmup: 0,
    };

    let steps = 12u64;
    for (title, rows) in [
        ("table1 (vgg_tiny, momentum)", experiments::table1_rows("momentum", steps)),
        ("table2 (resnet_mini, momentum)", experiments::table2_rows("momentum", steps)),
    ] {
        println!("\n# {title}, {steps}-step probes");
        for row in rows {
            let mut cfg = row.cfg.clone();
            cfg.eval_every = 0;
            cfg.log_every = 0;
            let mut t = Trainer::new(&client, &man, cfg)?;
            t.train_step()?; // warm
            let r = b.run(&format!("{title}/{}", row.label), || {
                t.train_step().unwrap();
            });
            // Finish the probe horizon for a stable ratio estimate.
            while t.step_count() < steps {
                t.train_step()?;
            }
            println!(
                "bench {:<52} step={:>9.1?} ratio={:>10.1} loss={:.3}",
                format!("{title}/{}", row.label),
                r.mean,
                t.metrics.compression_ratio(),
                t.metrics.final_loss()
            );
        }
    }
    Ok(())
}
