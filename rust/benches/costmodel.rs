//! A5 bench: emit the Section-5 speedup series (the paper's analytic
//! "figure") and measure the cost-model evaluation itself.

use vgc::bench::Bencher;
use vgc::comm::costmodel::{speedup_series, LinkModel};
use vgc::experiments;

fn main() {
    // The series itself IS the experiment artifact — print it.
    print!("{}", experiments::costmodel_report());

    // And the evaluation cost (trivially cheap; tracked so nobody
    // accidentally turns the closed form into something expensive).
    let b = Bencher::default();
    b.report("costmodel/speedup_series 4x8 grid", || {
        let rows = speedup_series(
            25_500_000,
            &[2, 4, 8, 16],
            &[1.0, 10.0, 100.0, 1000.0, 10000.0, 100000.0, 1e6, 1e7],
            LinkModel::gige(),
        );
        std::hint::black_box(rows.len());
    });
}
