//! Communication-fabric bench: ring allgatherv vs ring allreduce at the
//! byte-movement level, over realistic message-size mixes, plus the
//! Section-5 modeled times for the same traffic.

use vgc::bench::Bencher;
use vgc::comm::allgatherv::ring_allgatherv;
use vgc::comm::allreduce::ring_allreduce;
use vgc::comm::costmodel::{CostModel, LinkModel};
use vgc::util::rng::Pcg32;

fn main() {
    let b = Bencher::default();
    let n = 250_000usize; // f32 elements per worker (1 MB)

    for p in [4usize, 8, 16] {
        // Uncompressed baseline: full f32 vectors through allreduce.
        let inputs: Vec<Vec<f32>> = (0..p)
            .map(|w| {
                let mut rng = Pcg32::new(w as u64, 7);
                (0..n).map(|_| rng.next_f32()).collect()
            })
            .collect();
        b.report_throughput(
            &format!("ring_allreduce/p={p}/n={n}"),
            (n * p) as f64,
            "elem",
            || {
                let r = ring_allreduce(&inputs);
                std::hint::black_box(r.traffic.rounds);
            },
        );

        // Compressed: sparse messages at ratio ~100 (c=100).
        let msgs: Vec<Vec<u8>> = (0..p)
            .map(|w| {
                let mut rng = Pcg32::new(w as u64, 9);
                (0..n * 4 / 100).map(|_| rng.next_u32() as u8).collect()
            })
            .collect();
        b.report_throughput(
            &format!("ring_allgatherv/p={p}/c=100"),
            msgs.iter().map(|m| m.len()).sum::<usize>() as f64,
            "B",
            || {
                let r = ring_allgatherv(&msgs);
                std::hint::black_box(r.traffic.rounds);
            },
        );

        // The Section-5 modeled wall-clock for the same geometry.
        let model = CostModel::new(p, n as u64, LinkModel::gige());
        println!(
            "  modeled 1GbE: T_r = {:.3} ms, T_v(c=100) = {:.3} ms, speedup {:.1}x",
            model.t_allreduce() * 1e3,
            model.t_allgatherv_ratio(100.0) * 1e3,
            model.speedup(100.0)
        );
    }
}
