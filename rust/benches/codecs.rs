//! Hot-path bench: encode/decode throughput of every codec over a
//! gradient-realistic 1M-element vector (ResNet-50-scale stream slice).
//!
//! This is the L3 cost the paper's Sec. 5 argues must stay negligible
//! next to CalcGrad — the numbers here feed EXPERIMENTS.md §Perf.

use vgc::bench::Bencher;
use vgc::compress::{Codec, CodecEngine, CodecSpec};
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::rng::Pcg32;
use vgc::util::threadpool::ThreadPool;

fn main() {
    let n = 1_000_000usize;
    let layout = Layout::uniform(n, 4096);
    let mut rng = Pcg32::new(42, 1);
    let gsum = testkit::gradient_vec(&mut rng, n);
    let gsumsq: Vec<f32> = gsum.iter().map(|g| g * g * 1.5).collect();

    let specs = [
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.01 },
        CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 },
        CodecSpec::Qsgd { bits: 2, bucket: 128 },
        CodecSpec::TernGrad,
    ];

    let b = Bencher::default();
    println!("# codec encode/decode over N = {n} gradient elements");
    for spec in &specs {
        let mut codec = spec.build(&layout, 0);
        // Steady-state: warm the residual state before measuring.
        let msg0 = codec.encode_step(&gsum, &gsumsq);
        b.report_throughput(
            &format!("encode/{}", spec.label()),
            n as f64,
            "elem",
            || {
                let msg = codec.encode_step(&gsum, &gsumsq);
                std::hint::black_box(msg.elements);
            },
        );
        let mut out = vec![0.0f32; n];
        b.report_throughput(
            &format!("decode/{}", spec.label()),
            n as f64,
            "elem",
            || {
                codec.decode_into(&msg0.bytes, &mut out).unwrap();
                std::hint::black_box(out[0]);
            },
        );
    }

    // Engine: 8 simulated workers end-to-end (encode all + decode all),
    // serial path vs the parallel sharded engine — the §Perf headline.
    let p = 8usize;
    let mut rng = Pcg32::new(43, 2);
    let inputs: Vec<(Vec<f32>, Vec<f32>)> = (0..p)
        .map(|_| {
            let g = testkit::gradient_vec(&mut rng, n);
            let q: Vec<f32> = g.iter().map(|x| x * x * 1.5).collect();
            (g, q)
        })
        .collect();
    let gs: Vec<&[f32]> = inputs.iter().map(|(g, _)| g.as_slice()).collect();
    let qs: Vec<&[f32]> = inputs.iter().map(|(_, q)| q.as_slice()).collect();
    let spec = CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 };
    println!("# engine: vgc, {p} workers, serial vs parallel");
    for threads in [1usize, ThreadPool::available()] {
        let mut codecs: Vec<Box<dyn Codec>> =
            (0..p).map(|w| spec.build(&layout, w as u64)).collect();
        let mut engine = CodecEngine::new(threads);
        let mut update = vec![0.0f32; n];
        // Warm state/buffers and capture messages for the decode bench.
        let msgs: Vec<Vec<u8>> = {
            let mut refs: Vec<&mut dyn Codec> =
                codecs.iter_mut().map(|c| &mut **c).collect();
            engine.encode_all(&mut refs, &gs, &qs);
            engine.messages().to_vec()
        };
        {
            let mut refs: Vec<&mut dyn Codec> =
                codecs.iter_mut().map(|c| &mut **c).collect();
            b.report_throughput(
                &format!("engine-encode/vgc/p{p}/t{threads}"),
                (p * n) as f64,
                "elem",
                || {
                    engine.encode_all(&mut refs, &gs, &qs);
                },
            );
        }
        b.report_throughput(
            &format!("engine-decode/vgc/p{p}/t{threads}"),
            (p * n) as f64,
            "elem",
            || {
                engine.decode_all(&*codecs[0], &msgs, &mut update).unwrap();
                std::hint::black_box(update[0]);
            },
        );
    }
}
