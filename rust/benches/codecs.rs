//! Hot-path bench: encode/decode throughput of every codec over a
//! gradient-realistic 1M-element vector (ResNet-50-scale stream slice).
//!
//! This is the L3 cost the paper's Sec. 5 argues must stay negligible
//! next to CalcGrad — the numbers here feed EXPERIMENTS.md §Perf.

use vgc::bench::Bencher;
use vgc::compress::CodecSpec;
use vgc::model::Layout;
use vgc::testkit;
use vgc::util::rng::Pcg32;

fn main() {
    let n = 1_000_000usize;
    let layout = Layout::uniform(n, 4096);
    let mut rng = Pcg32::new(42, 1);
    let gsum = testkit::gradient_vec(&mut rng, n);
    let gsumsq: Vec<f32> = gsum.iter().map(|g| g * g * 1.5).collect();

    let specs = [
        CodecSpec::None,
        CodecSpec::Vgc { alpha: 1.5, zeta: 0.999 },
        CodecSpec::Strom { tau: 0.01 },
        CodecSpec::Hybrid { tau: 0.01, alpha: 2.0, zeta: 0.999 },
        CodecSpec::Qsgd { bits: 2, bucket: 128 },
        CodecSpec::TernGrad,
    ];

    let b = Bencher::default();
    println!("# codec encode/decode over N = {n} gradient elements");
    for spec in &specs {
        let mut codec = spec.build(&layout, 0);
        // Steady-state: warm the residual state before measuring.
        let msg0 = codec.encode_step(&gsum, &gsumsq);
        b.report_throughput(
            &format!("encode/{}", spec.label()),
            n as f64,
            "elem",
            || {
                let msg = codec.encode_step(&gsum, &gsumsq);
                std::hint::black_box(msg.elements);
            },
        );
        let mut out = vec![0.0f32; n];
        b.report_throughput(
            &format!("decode/{}", spec.label()),
            n as f64,
            "elem",
            || {
                codec.decode_into(&msg0.bytes, &mut out).unwrap();
                std::hint::black_box(out[0]);
            },
        );
    }
}
