"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps).

This is the CORE correctness signal for the compile path: everything the
Rust coordinator consumes flows through these kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.criterion import criterion
from compile.kernels.moments import moments, scaled_moments
from compile.kernels.ref import criterion_ref, moments_ref

SETTINGS = dict(max_examples=40, deadline=None)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


class TestMoments:
    @given(
        b=st.integers(1, 33),
        n=st.integers(1, 2000),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref_f32(self, b, n, seed):
        g = _rand((b, n), np.float32, seed)
        s, ss = moments(g)
        rs, rss = moments_ref(g)
        np.testing.assert_allclose(s, rs, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(ss, rss, rtol=1e-5, atol=1e-5)

    @given(
        b=st.integers(1, 8),
        n=st.integers(1, 700),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref_bf16_input(self, b, n, seed):
        g = jnp.asarray(_rand((b, n), np.float32, seed), jnp.bfloat16)
        s, ss = moments(g)
        rs, rss = moments_ref(g)
        np.testing.assert_allclose(s, rs, rtol=1e-2, atol=1e-2)
        np.testing.assert_allclose(ss, rss, rtol=1e-2, atol=1e-2)

    @pytest.mark.parametrize("n", [1, 511, 512, 513, 1024, 4096])
    def test_tile_boundaries(self, n):
        g = _rand((4, n), np.float32, n)
        s, ss = moments(g)
        rs, rss = moments_ref(g)
        np.testing.assert_allclose(s, rs, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ss, rss, rtol=1e-5, atol=1e-6)

    def test_outputs_f32(self):
        g = _rand((2, 10), np.float32, 0)
        s, ss = moments(g)
        assert s.dtype == jnp.float32 and ss.dtype == jnp.float32

    def test_zero_input(self):
        g = np.zeros((5, 100), np.float32)
        s, ss = moments(g)
        assert np.all(np.asarray(s) == 0) and np.all(np.asarray(ss) == 0)

    def test_sumsq_nonnegative(self):
        g = _rand((16, 333), np.float32, 7)
        _, ss = moments(g)
        assert np.all(np.asarray(ss) >= 0)

    def test_single_sample(self):
        g = _rand((1, 77), np.float32, 3)
        s, ss = moments(g)
        np.testing.assert_allclose(s, g[0], rtol=1e-6)
        np.testing.assert_allclose(ss, g[0] ** 2, rtol=1e-6)

    @given(
        b=st.integers(1, 16),
        n=st.integers(1, 600),
        batch=st.integers(1, 256),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_scaled_moments_algorithm1_increments(self, b, n, batch, seed):
        """scaled_moments == (Σg/B, Σg²/B²) — the exact Alg.-1 increments."""
        g = _rand((b, n), np.float32, seed)
        s, ss = scaled_moments(g, batch)
        np.testing.assert_allclose(s, g.sum(0) / batch, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            ss, (g**2).sum(0) / batch**2, rtol=1e-5, atol=1e-7
        )

    @pytest.mark.parametrize("tile", [8, 128, 512, 2048])
    def test_tile_size_invariance(self, tile):
        """The BlockSpec tiling must not change the result."""
        g = _rand((8, 1000), np.float32, 11)
        s, ss = moments(g, tile_n=tile)
        rs, rss = moments_ref(g)
        np.testing.assert_allclose(s, rs, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(ss, rss, rtol=1e-5, atol=1e-6)


class TestCriterion:
    @given(
        n=st.integers(1, 3000),
        alpha=st.floats(0.5, 4.0),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, n, alpha, seed):
        rng = np.random.default_rng(seed)
        r = rng.standard_normal(n).astype(np.float32)
        v = np.abs(rng.standard_normal(n)).astype(np.float32) * 0.1
        m = criterion(r, v, alpha)
        mr = criterion_ref(r, v, alpha)
        np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))

    def test_zero_variance_always_sends_nonzero_r(self):
        r = np.array([1.0, -2.0, 0.0], np.float32)
        v = np.zeros(3, np.float32)
        m = np.asarray(criterion(r, v, 2.0))
        # r² > 0 sends; r == 0 gives 0 > 0 == False.
        np.testing.assert_array_equal(m, [1.0, 1.0, 0.0])

    def test_alpha_monotonicity(self):
        """Larger α can only send a subset of what smaller α sends."""
        rng = np.random.default_rng(0)
        r = rng.standard_normal(2048).astype(np.float32)
        v = np.abs(rng.standard_normal(2048)).astype(np.float32)
        m1 = np.asarray(criterion(r, v, 1.0))
        m2 = np.asarray(criterion(r, v, 2.0))
        assert np.all(m2 <= m1)

    def test_boundary_strict_inequality(self):
        """Criterion is strict: r² == αv must NOT send (paper Eq. 3)."""
        r = np.array([2.0], np.float32)
        v = np.array([4.0], np.float32)
        assert np.asarray(criterion(r, v, 1.0))[0] == 0.0

    def test_padding_never_sends(self):
        """N far from a tile multiple: pad lanes must not leak into output."""
        n = 513
        r = np.ones(n, np.float32)
        v = np.zeros(n, np.float32)
        m = np.asarray(criterion(r, v, 1.0))
        assert m.shape == (n,) and np.all(m == 1.0)
