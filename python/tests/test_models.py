"""L2 model correctness: shapes, gradient-moment semantics, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _fake_batch(spec, workers, batch, seed=0):
    rng = np.random.default_rng(seed)
    shape = (workers, batch) + tuple(spec.sample_shape)
    if np.dtype(spec.sample_dtype) == np.int32:
        xs = rng.integers(0, spec.n_classes, size=shape).astype(np.int32)
    else:
        xs = rng.standard_normal(shape).astype(np.float32)
    ys = rng.integers(0, spec.n_classes, size=(workers, batch)).astype(np.int32)
    return jnp.asarray(xs), jnp.asarray(ys)


class TestRegistry:
    def test_all_models_present(self):
        assert set(M.REGISTRY) == {
            "mlp",
            "vgg_tiny",
            "vgg_cifar",
            "resnet_mini",
            "transformer",
        }

    @pytest.mark.parametrize("name", ["mlp", "vgg_tiny", "resnet_mini", "transformer"])
    def test_init_flat_groups_cover_params(self, name):
        spec = M.REGISTRY[name]
        flat0, _, groups = M.init_flat(spec)
        total = sum(g["len"] for g in groups)
        assert total == flat0.shape[0]
        # Groups are contiguous and ordered.
        off = 0
        for g in groups:
            assert g["offset"] == off
            off += g["len"]

    def test_init_deterministic(self):
        spec = M.REGISTRY["mlp"]
        a, _, _ = M.init_flat(spec, seed=0)
        b, _, _ = M.init_flat(spec, seed=0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c, _, _ = M.init_flat(spec, seed=1)
        assert not np.array_equal(np.asarray(a), np.asarray(c))


class TestGradMoments:
    def test_mlp_matches_direct_per_sample_grads(self):
        """step() must equal naive per-sample value_and_grad moments."""
        spec = M.REGISTRY["mlp"]
        flat0, unravel, _ = M.init_flat(spec)
        p, b, c = 2, 4, 2
        step = M.make_grad_moments(spec, unravel, p, b, c)
        xs, ys = _fake_batch(spec, p, b)
        loss, gsum, gsumsq = jax.jit(step)(flat0, xs, ys)

        for w in range(p):
            gs = []
            ls = []
            for z in range(b):
                def loss_flat(pf, xz=xs[w, z], yz=ys[w, z]):
                    return spec.per_sample_loss(unravel(pf), xz, yz)

                lz, gz = jax.value_and_grad(loss_flat)(flat0)
                gs.append(np.asarray(gz))
                ls.append(float(lz))
            gstack = np.stack(gs)
            np.testing.assert_allclose(float(loss[w]), np.mean(ls), rtol=1e-5)
            np.testing.assert_allclose(
                np.asarray(gsum[w]), gstack.sum(0) / b, rtol=2e-4, atol=1e-6
            )
            np.testing.assert_allclose(
                np.asarray(gsumsq[w]), (gstack**2).sum(0) / b**2,
                rtol=2e-4, atol=1e-8,
            )

    def test_chunking_invariance(self):
        """Microbatch chunk size must not change the moments."""
        spec = M.REGISTRY["mlp"]
        flat0, unravel, _ = M.init_flat(spec)
        xs, ys = _fake_batch(spec, 2, 8)
        out_c2 = jax.jit(M.make_grad_moments(spec, unravel, 2, 8, 2))(flat0, xs, ys)
        out_c8 = jax.jit(M.make_grad_moments(spec, unravel, 2, 8, 8))(flat0, xs, ys)
        for a, b_ in zip(out_c2, out_c8):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), rtol=2e-4, atol=1e-7
            )

    @pytest.mark.parametrize("name", ["vgg_tiny", "resnet_mini", "transformer"])
    def test_shapes_and_finiteness(self, name):
        spec = M.REGISTRY[name]
        flat0, unravel, _ = M.init_flat(spec)
        p, b, c = 2, 4, 2
        step = M.make_grad_moments(spec, unravel, p, b, c)
        xs, ys = _fake_batch(spec, p, b)
        loss, gsum, gsumsq = jax.jit(step)(flat0, xs, ys)
        n = flat0.shape[0]
        assert loss.shape == (p,)
        assert gsum.shape == (p, n)
        assert gsumsq.shape == (p, n)
        assert np.all(np.isfinite(np.asarray(loss)))
        assert np.all(np.isfinite(np.asarray(gsum)))
        assert np.all(np.asarray(gsumsq) >= 0)

    def test_workers_see_different_data(self):
        """Different shards must give different moments (no aliasing)."""
        spec = M.REGISTRY["mlp"]
        flat0, unravel, _ = M.init_flat(spec)
        step = M.make_grad_moments(spec, unravel, 2, 4, 4)
        xs, ys = _fake_batch(spec, 2, 4)
        _, gsum, _ = jax.jit(step)(flat0, xs, ys)
        assert not np.allclose(np.asarray(gsum[0]), np.asarray(gsum[1]))

    def test_identical_shards_give_identical_moments(self):
        spec = M.REGISTRY["mlp"]
        flat0, unravel, _ = M.init_flat(spec)
        step = M.make_grad_moments(spec, unravel, 2, 4, 2)
        xs, ys = _fake_batch(spec, 1, 4)
        xs2 = jnp.concatenate([xs, xs], axis=0)
        ys2 = jnp.concatenate([ys, ys], axis=0)
        loss, gsum, gsumsq = jax.jit(step)(flat0, xs2, ys2)
        np.testing.assert_allclose(
            np.asarray(gsum[0]), np.asarray(gsum[1]), rtol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(gsumsq[0]), np.asarray(gsumsq[1]), rtol=1e-6
        )


class TestTrainability:
    def test_mlp_loss_decreases_under_sgd(self):
        """Sanity: the lowered step's gsum is a usable descent direction."""
        spec = M.REGISTRY["mlp"]
        flat0, unravel, _ = M.init_flat(spec)
        p, b = 2, 16
        step = jax.jit(M.make_grad_moments(spec, unravel, p, b, 16))
        xs, ys = _fake_batch(spec, p, b, seed=42)
        params = flat0
        losses = []
        for _ in range(30):
            loss, gsum, _ = step(params, xs, ys)
            losses.append(float(loss.mean()))
            grad = gsum.mean(axis=0)  # allreduce-mean equivalent
            params = params - 0.5 * grad
        assert losses[-1] < losses[0] * 0.5, losses

    def test_transformer_loss_decreases(self):
        spec = M.REGISTRY["transformer"]
        flat0, unravel, _ = M.init_flat(spec)
        step = jax.jit(M.make_grad_moments(spec, unravel, 1, 4, 2))
        xs, ys = _fake_batch(spec, 1, 4, seed=3)
        params = flat0
        first = last = None
        for i in range(10):
            loss, gsum, _ = step(params, xs, ys)
            if i == 0:
                first = float(loss.mean())
            last = float(loss.mean())
            params = params - 0.5 * gsum.mean(axis=0)
        assert last < first


class TestEval:
    def test_forward_logits_shape(self):
        spec = M.REGISTRY["mlp"]
        flat0, unravel, _ = M.init_flat(spec)
        fwd = jax.jit(M.make_forward(spec, unravel))
        x = jnp.zeros((8,) + tuple(spec.sample_shape), spec.sample_dtype)
        logits = fwd(flat0, x)
        assert logits.shape == (8, spec.n_classes)

    def test_eval_loss_scalar(self):
        spec = M.REGISTRY["transformer"]
        flat0, unravel, _ = M.init_flat(spec)
        ev = jax.jit(M.make_eval_loss(spec, unravel))
        x = jnp.zeros((4,) + tuple(spec.sample_shape), spec.sample_dtype)
        val = ev(flat0, x)
        assert val.shape == () and np.isfinite(float(val))
