"""AOT path: HLO text generation and manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


class TestHloText:
    def test_simple_fn_lowers_to_hlo_text(self):
        def fn(x):
            return (x * 2.0 + 1.0,)

        spec = jax.ShapeDtypeStruct((4,), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fn).lower(spec))
        assert "ENTRY" in text and "HloModule" in text

    def test_grad_artifact_lowers(self):
        spec = M.REGISTRY["mlp"]
        _, unravel, _ = M.init_flat(spec)
        step = M.make_grad_moments(spec, unravel, 2, 4, 2)
        flat0, _, _ = M.init_flat(spec)
        n = flat0.shape[0]
        lowered = jax.jit(step).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((2, 4, 64), jnp.float32),
            jax.ShapeDtypeStruct((2, 4), jnp.int32),
        )
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        # Output tuple carries loss[P] + the two [P, N] moment tensors.
        assert f"f32[2,{n}]" in text


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    ),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(
            os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json"
        )
        with open(path) as f:
            return json.load(f), os.path.dirname(path)

    def test_models_listed(self, manifest):
        man, _ = manifest
        names = {m["name"] for m in man["models"]}
        assert {"mlp", "vgg_tiny", "resnet_mini", "transformer"} <= names

    def test_artifact_files_exist(self, manifest):
        man, art_dir = manifest
        for m in man["models"]:
            for key in ("grad_hlo", "eval_hlo", "params_bin"):
                assert os.path.exists(os.path.join(art_dir, m[key])), m[key]

    def test_params_bin_size_matches(self, manifest):
        man, art_dir = manifest
        for m in man["models"]:
            size = os.path.getsize(os.path.join(art_dir, m["params_bin"]))
            assert size == 4 * m["n_params"]

    def test_groups_partition_param_vector(self, manifest):
        man, _ = manifest
        for m in man["models"]:
            off = 0
            for g in m["groups"]:
                assert g["offset"] == off
                assert g["len"] > 0
                off += g["len"]
            assert off == m["n_params"]

    def test_params_bin_matches_reinit(self, manifest):
        """The exported initial params must be reproducible from the seed."""
        man, art_dir = manifest
        entry = next(m for m in man["models"] if m["name"] == "mlp")
        flat0, _, _ = M.init_flat(M.REGISTRY["mlp"], seed=entry["seed"])
        on_disk = np.fromfile(
            os.path.join(art_dir, entry["params_bin"]), dtype="<f4"
        )
        np.testing.assert_array_equal(on_disk, np.asarray(flat0))
