"""L2: model registry and AOT step-function builders.

This module turns each registered model into the two jitted functions the
Rust coordinator executes through PJRT:

  * ``grad_moments`` — one synchronous data-parallel training step's
    *compute* half: for P workers with per-worker batch B, returns
    ``(loss[P], gsum[P,N], gsumsq[P,N])`` where ``gsum = Σ_z ∇f_z / B``
    and ``gsumsq = Σ_z (∇f_z / B)²`` — exactly the per-step increments of
    Algorithm 1's ``r`` and ``v`` accumulators. Per-sample gradients come
    from ``vmap(value_and_grad)`` over microbatch chunks (scanned, so peak
    memory is ``P × C × N``), reduced by the fused Pallas moments kernel
    (L1), and the whole thing is vmapped over the worker axis so one XLA
    call computes every worker's moments.

  * ``forward`` / ``eval_loss`` — the evaluation half (logits for
    classifiers, mean next-token loss for the LM).

Everything here is build-time only; the lowered HLO text is the interface
to Rust (see ``aot.py``). The flat parameter layout (and hence the
quantization groups — the paper's per-matrix ``M_k`` scopes) is defined by
``ravel_pytree`` order and exported via the manifest.
"""

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from .kernels.moments import moments
from .models import mlp, resnet, transformer, vgg


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A registered model: init + per-sample loss + batched forward."""

    name: str
    init: Callable[[jax.Array], Any]
    # per_sample_loss(params, x_z, y_z) -> scalar loss for ONE sample.
    per_sample_loss: Callable[[Any, jax.Array, jax.Array], jax.Array]
    # batched_apply(params, x[B,...]) -> logits [B, K]; None for LMs.
    batched_apply: Any
    sample_shape: tuple  # shape of one input sample (no batch dim)
    sample_dtype: Any
    label_dtype: Any
    n_classes: int
    kind: str  # "classifier" | "lm"
    # Default reproduction-scale launch config (overridable in aot.py).
    default_workers: int = 4
    default_batch: int = 16
    default_chunk: int = 8
    default_eval_batch: int = 256


def _image_spec(name, init_fn, apply_fn, loss_fn, img, workers, batch, chunk):
    return ModelSpec(
        name=name,
        init=init_fn,
        per_sample_loss=lambda p, x, y: loss_fn(p, x[None], y[None]),
        batched_apply=apply_fn,
        sample_shape=(img, img, 3),
        sample_dtype=jnp.float32,
        label_dtype=jnp.int32,
        n_classes=10,
        kind="classifier",
        default_workers=workers,
        default_batch=batch,
        default_chunk=chunk,
    )


def _make_registry():
    reg = {}
    reg["mlp"] = ModelSpec(
        name="mlp",
        init=lambda key: mlp.init(key),
        per_sample_loss=lambda p, x, y: mlp.loss(p, x[None], y[None]),
        batched_apply=mlp.apply,
        sample_shape=(64,),
        sample_dtype=jnp.float32,
        label_dtype=jnp.int32,
        n_classes=10,
        kind="classifier",
        default_workers=4,
        default_batch=16,
        default_chunk=16,
    )
    # Table-1 workload (paper: 8 workers, B=64, 32x32; scaled to 16x16,
    # B=8 for the single-core CPU testbed — DESIGN.md §Substitutions).
    reg["vgg_tiny"] = _image_spec(
        "vgg_tiny", vgg.init_tiny, vgg.apply_tiny, vgg.loss_tiny, 16, 8, 8, 8
    )
    # Full-width-scaled Table-3 topology on 32x32 (optional, --full).
    reg["vgg_cifar"] = _image_spec(
        "vgg_cifar", vgg.init_cifar, vgg.apply_cifar, vgg.loss_cifar, 32, 2, 8, 4
    )
    # Table-2 workload (paper: 16 workers, B=32, ResNet-50; scaled to
    # B=4 — the 16-worker axis is the part Table 2 adds over Table 1).
    reg["resnet_mini"] = _image_spec(
        "resnet_mini", resnet.init_mini, resnet.apply_mini, resnet.loss_mini,
        16, 16, 4, 4,
    )
    # End-to-end driver workload: causal LM on a synthetic token stream.
    seq_len = 64
    reg["transformer"] = ModelSpec(
        name="transformer",
        init=lambda key: transformer.init(key, max_len=seq_len),
        per_sample_loss=lambda p, x, y: transformer.loss(p, x),
        batched_apply=None,
        sample_shape=(seq_len,),
        sample_dtype=jnp.int32,
        label_dtype=jnp.int32,
        n_classes=256,  # vocab
        kind="lm",
        default_workers=4,
        default_batch=8,
        default_chunk=4,
        default_eval_batch=32,
    )
    return reg


REGISTRY = _make_registry()


def init_flat(spec, seed=0):
    """Initial flat parameter vector, its unravel fn, and group layout.

    Returns ``(flat0, unravel, groups)`` where ``groups`` is a list of
    ``{"name", "offset", "len"}`` dicts in flat-vector order — the
    quantization group table exported to the coordinator (Sec. 4.2's
    per-weight-matrix ``M_k`` scopes).
    """
    params0 = spec.init(jax.random.PRNGKey(seed))
    flat0, unravel = ravel_pytree(params0)
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params0)[0]
    groups = []
    offset = 0
    for path, leaf in leaves_with_path:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        name = jax.tree_util.keystr(path)
        groups.append({"name": name, "offset": offset, "len": size})
        offset += size
    assert offset == flat0.shape[0]
    return flat0, unravel, groups


def make_grad_moments(spec, unravel, workers, batch, chunk):
    """Build the multi-worker training-step compute function.

    Signature of the returned function (the grad artifact's interface):
      ``f(params[N] f32, xs[P,B,*sample], ys[P,B] int32)
        -> (loss[P] f32, gsum[P,N] f32, gsumsq[P,N] f32)``
    """
    assert batch % chunk == 0, "batch must be divisible by chunk"
    n_chunks = batch // chunk

    def per_sample_value_and_grad(params_flat, x_z, y_z):
        def loss_flat(pf):
            return spec.per_sample_loss(unravel(pf), x_z, y_z)

        return jax.value_and_grad(loss_flat)(params_flat)

    def worker(params_flat, xw, yw):
        n = params_flat.shape[0]
        xc = xw.reshape((n_chunks, chunk) + xw.shape[1:])
        yc = yw.reshape((n_chunks, chunk))

        def body(carry, xy):
            loss_acc, s_acc, ss_acc = carry
            x_i, y_i = xy
            losses, g = jax.vmap(per_sample_value_and_grad, in_axes=(None, 0, 0))(
                params_flat, x_i, y_i
            )  # losses [C], g [C, N]
            s, ss = moments(g)  # L1 fused kernel: Σg, Σg² over the chunk
            return (loss_acc + losses.sum(), s_acc + s, ss_acc + ss), None

        init = (
            jnp.zeros((), jnp.float32),
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), jnp.float32),
        )
        (loss_sum, s_tot, ss_tot), _ = jax.lax.scan(body, init, (xc, yc))
        inv_b = 1.0 / float(batch)
        # Algorithm-1 increments: r += Σg/B, v += Σ(g/B)² = Σg²/B².
        return loss_sum * inv_b, s_tot * inv_b, ss_tot * (inv_b * inv_b)

    def step(params_flat, xs, ys):
        return jax.vmap(worker, in_axes=(None, 0, 0))(params_flat, xs, ys)

    return step


def make_forward(spec, unravel):
    """Batched logits function ``f(params[N], x[Be,*sample]) -> [Be, K]``."""
    assert spec.kind == "classifier"

    def forward(params_flat, x):
        return spec.batched_apply(unravel(params_flat), x)

    return forward


def make_eval_loss(spec, unravel):
    """Mean loss over an eval batch ``f(params[N], x[Be,*]) -> scalar``."""

    def eval_loss(params_flat, x):
        params = unravel(params_flat)
        losses = jax.vmap(
            lambda xz, yz: spec.per_sample_loss(params, xz, yz), in_axes=(0, 0)
        )(x, jnp.zeros(x.shape[0], spec.label_dtype))
        return losses.mean()

    return eval_loss


def make_criterion():
    """Standalone Eq.-3 decision function over an N-vector (XLA offload)."""
    from .kernels.criterion import criterion

    def fn(r, v, alpha):
        return criterion(r, v, alpha)

    return fn


def make_moments_bench():
    """Standalone fused-moments function (kernel micro-bench artifact)."""

    def fn(g):
        return moments(g)

    return fn
