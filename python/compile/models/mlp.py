"""Quickstart MLP classifier (flat-vector inputs).

The smallest model in the registry; used by ``examples/quickstart.rs`` and
by the Python test-suite as a fast correctness workload.
"""

import jax

from .common import cross_entropy, dense, dense_init, relu


def init(key, d_in=64, d_hidden=128, n_classes=10, depth=2):
    """Parameter pytree for a ``depth``-hidden-layer ReLU MLP."""
    keys = jax.random.split(key, depth + 1)
    params = {"layers": []}
    d = d_in
    for i in range(depth):
        params["layers"].append(dense_init(keys[i], d, d_hidden))
        d = d_hidden
    params["head"] = dense_init(keys[depth], d, n_classes)
    return params


def apply(params, x):
    """Logits for ``x: [B, d_in]``."""
    h = x
    for layer in params["layers"]:
        h = relu(dense(layer, h))
    return dense(params["head"], h)


def loss(params, x, y):
    return cross_entropy(apply(params, x), y)
