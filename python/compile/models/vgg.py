"""VGG-like convolutional networks (paper Table 3, scaled).

The paper's CIFAR-10 network (Appendix D / Table 3) is a VGG-like stack:
``conv3-64 ×2, M, conv3-128 ×2, M, conv3-256 ×3, M, conv3-512 ×3, M,
conv3-512 ×3, M, fc-512, fc-10`` with BN+dropout (~15M parameters).

We provide two scaled variants (DESIGN.md §Substitutions — CPU-only
budget; BN/dropout dropped because Algorithm 1 requires per-sample
gradient semantics):

  * ``vgg_cifar`` — the Table-3 topology with channel widths divided by 4
    (16/32/64/128/128) and the two 512-fc head replaced by GAP + fc.
    Preserves the 5-stage, 13-conv structure.
  * ``vgg_tiny``  — a 3-stage 6-conv variant for 16x16 synthetic CIFAR;
    the default Table-1 reproduction workload (~150k params).
"""

import jax

from .common import (
    conv,
    conv_init,
    cross_entropy,
    dense,
    dense_init,
    head_init,
    global_avg_pool,
    max_pool,
    relu,
)

# Stage plans: list of stages; each stage is a list of conv output widths,
# followed by a max-pool.
_TINY_PLAN = [[16, 16], [32, 32], [64, 64]]
_CIFAR_PLAN = [[16, 16], [32, 32], [64, 64, 64], [128, 128, 128], [128, 128, 128]]


def _init_plan(key, plan, c_in, n_classes):
    params = {"convs": []}
    keys = jax.random.split(key, sum(len(s) for s in plan) + 1)
    k = 0
    c = c_in
    for stage in plan:
        for width in stage:
            params["convs"].append(conv_init(keys[k], c, width))
            c = width
            k += 1
    params["head"] = head_init(keys[k], c, n_classes)
    return params


def _apply_plan(plan, params, x):
    i = 0
    h = x
    for stage in plan:
        for _ in stage:
            h = relu(conv(params["convs"][i], h))
            i += 1
        h = max_pool(h)
    return dense(params["head"], global_avg_pool(h))


def init_tiny(key, c_in=3, n_classes=10):
    """~150k-param 3-stage VGG for 16x16 inputs (Table-1 workload)."""
    return _init_plan(key, _TINY_PLAN, c_in, n_classes)


def apply_tiny(params, x):
    """Logits for ``x: [B, 16, 16, 3]``."""
    return _apply_plan(_TINY_PLAN, params, x)


def init_cifar(key, c_in=3, n_classes=10):
    """Width-scaled Table-3 topology for 32x32 inputs."""
    return _init_plan(key, _CIFAR_PLAN, c_in, n_classes)


def apply_cifar(params, x):
    """Logits for ``x: [B, 32, 32, 3]``."""
    return _apply_plan(_CIFAR_PLAN, params, x)


def loss_tiny(params, x, y):
    return cross_entropy(apply_tiny(params, x), y)


def loss_cifar(params, x, y):
    return cross_entropy(apply_cifar(params, x), y)
