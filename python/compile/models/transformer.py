"""Decoder-only transformer LM for the end-to-end training driver.

``examples/train_e2e.rs`` trains this model for a few hundred distributed
steps on a synthetic Markov token stream with VGC compression and logs the
loss curve (EXPERIMENTS.md §E2E). The model is a standard pre-LN causal
transformer; per-sample (= per-sequence) gradients are exact because
normalization is LayerNorm over features, never over the batch.

Scale is CPU-budgeted (~0.9M params by default — DESIGN.md
§Substitutions); depth/width are init-time arguments so the same code
lowers larger variants.
"""

import math

import jax
import jax.numpy as jnp

from .common import dense, dense_init, layer_norm, layer_norm_init


def init(key, vocab=256, d_model=128, n_heads=4, n_layers=4, max_len=64):
    keys = iter(jax.random.split(key, 2 + 6 * n_layers))
    params = {
        "tok_embed": jax.random.normal(next(keys), (vocab, d_model), jnp.float32)
        * 0.02,
        "pos_embed": jax.random.normal(next(keys), (max_len, d_model), jnp.float32)
        * 0.02,
        "blocks": [],
        "final_ln": layer_norm_init(d_model),
    }
    for _ in range(n_layers):
        params["blocks"].append(
            {
                "ln1": layer_norm_init(d_model),
                "qkv": dense_init(next(keys), d_model, 3 * d_model),
                "proj": dense_init(next(keys), d_model, d_model),
                "ln2": layer_norm_init(d_model),
                "fc1": dense_init(next(keys), d_model, 4 * d_model),
                "fc2": dense_init(next(keys), 4 * d_model, d_model),
            }
        )
    # n_heads is static model config, NOT a parameter: it must not enter the
    # flat vector the coordinator compresses. The registry threads it.
    return params


def _attention(block, x, n_heads):
    t, d = x.shape
    qkv = dense(block["qkv"], x)  # [T, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // n_heads

    def heads(a):
        return a.reshape(t, n_heads, hd).transpose(1, 0, 2)  # [H, T, hd]

    q, k, v = heads(q), heads(k), heads(v)
    scores = (q @ k.transpose(0, 2, 1)) / math.sqrt(hd)  # [H, T, T]
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(mask == 0, -1e9, scores)
    attn = jax.nn.softmax(scores, axis=-1)
    out = (attn @ v).transpose(1, 0, 2).reshape(t, d)
    return dense(block["proj"], out)


def apply(params, tokens, n_heads=4):
    """Logits ``[T, vocab]`` for one sequence ``tokens: [T] int32``.

    Single-sequence on purpose: the L2 step function vmaps this over the
    per-sample axis, which is exactly the per-sample gradient axis.
    """
    t = tokens.shape[0]
    h = params["tok_embed"][tokens] + params["pos_embed"][:t]
    for block in params["blocks"]:
        h = h + _attention(block, layer_norm(block["ln1"], h), n_heads)
        ff = layer_norm(block["ln2"], h)
        ff = dense(block["fc2"], jax.nn.gelu(dense(block["fc1"], ff)))
        h = h + ff
    h = layer_norm(params["final_ln"], h)
    return h @ params["tok_embed"].T  # weight-tied head


def loss(params, tokens, _unused_label=None, n_heads=4):
    """Next-token cross-entropy over one sequence."""
    logits = apply(params, tokens[:-1], n_heads=n_heads)
    targets = tokens[1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    return nll.mean()
