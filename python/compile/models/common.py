"""Minimal pure-jnp layer library shared by the L2 models.

Parameters are plain pytrees (nested dicts of jnp arrays) so that
``jax.flatten_util.ravel_pytree`` gives a stable flat-vector layout; the
flat layout (offsets per named tensor) is exported to the Rust coordinator
through ``artifacts/manifest.json`` and defines the quantization groups
(the paper's per-weight-matrix ``M_k`` scopes, Sec. 4.2).

Design constraints:
  * No batch normalization and no dropout: the paper's Algorithm 1 needs
    per-sample gradients, and BN couples samples within a batch (and
    dropout would need a threaded PRNG through the AOT interface). The
    paper's VGG-like net uses BN+dropout; we substitute parameter-free
    scaled initialization (documented in DESIGN.md). Per-sample gradient
    semantics are exact for every layer used here.
  * Everything f32; shapes NHWC for images.
"""

import math

import jax
import jax.numpy as jnp


def dense_init(key, d_in, d_out):
    """He-initialized dense layer ``{w: [d_in, d_out], b: [d_out]}``."""
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * math.sqrt(2.0 / d_in)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def head_init(_key, d_in, d_out):
    """Zero-initialized classifier head.

    Without batch norm the deep conv stacks produce hot logits under He
    init (initial CE ≫ ln K, gradient norms in the hundreds), which
    blows up momentum training. A zero head gives exactly ln K initial
    loss and well-scaled first gradients.
    """
    return {
        "w": jnp.zeros((d_in, d_out), jnp.float32),
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p, x):
    return x @ p["w"] + p["b"]


def conv_init(key, c_in, c_out, k=3):
    """He-initialized conv ``{w: [k, k, c_in, c_out], b: [c_out]}`` (HWIO)."""
    fan_in = k * k * c_in
    w = jax.random.normal(key, (k, k, c_in, c_out), jnp.float32) * math.sqrt(
        2.0 / fan_in
    )
    return {"w": w, "b": jnp.zeros((c_out,), jnp.float32)}


def conv(p, x, stride=1):
    """3x3 SAME conv over NHWC input."""
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def max_pool(x):
    """2x2 stride-2 max pool over NHWC."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def global_avg_pool(x):
    """NHWC -> NC mean over spatial dims."""
    return x.mean(axis=(1, 2))


def relu(x):
    return jax.nn.relu(x)


def layer_norm_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layer_norm(p, x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def cross_entropy(logits, labels):
    """Mean cross-entropy of ``logits [.., K]`` vs int ``labels [..]``."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def accuracy(logits, labels):
    return (logits.argmax(axis=-1) == labels).astype(jnp.float32).mean()
