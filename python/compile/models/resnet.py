"""Residual network for the scaled Table-2 (ImageNet/ResNet-50) workload.

The paper's second evaluation trains ResNet-50 on ImageNet with 16
workers. We substitute ``resnet_mini`` — a 3-stage pre-activation-style
residual net on 16x16 synthetic images (DESIGN.md §Substitutions). It
preserves the properties Table 2 exercises relative to Table 1: deeper
topology, residual gradient flow, and a larger worker count.

Normalization-free residual blocks: each residual branch is scaled by a
learnable per-block scalar initialised at 0 (SkipInit), which reproduces
BN's trainability benefit without coupling samples — required for exact
per-sample gradients.
"""

import jax
import jax.numpy as jnp

from .common import (
    conv,
    conv_init,
    cross_entropy,
    dense,
    dense_init,
    head_init,
    global_avg_pool,
    relu,
)

# (width, n_blocks) per stage; stride-2 transition between stages.
# Widths/depth sized for the single-core CPU testbed (DESIGN.md
# §Substitutions): per-sample-gradient convs are ~5× batched convs, and
# Table 2 needs 16 workers; this plan keeps a 3-stage residual topology
# at ~0.7 s/step.
_MINI_PLAN = [(12, 1), (24, 1), (48, 1)]


def _block_init(key, c):
    k1, k2 = jax.random.split(key)
    return {
        "conv1": conv_init(k1, c, c),
        "conv2": conv_init(k2, c, c),
        # SkipInit residual scale: blocks start as identity.
        "scale": jnp.zeros((), jnp.float32),
    }


def _block_apply(p, x):
    h = relu(conv(p["conv1"], x))
    h = conv(p["conv2"], h)
    return relu(x + p["scale"] * h)


def init_mini(key, c_in=3, n_classes=10):
    """~200k-param residual net for 16x16 inputs (Table-2 workload)."""
    n_keys = 1 + sum(n + 1 for _, n in _MINI_PLAN) + 1
    keys = iter(jax.random.split(key, n_keys))
    params = {"stem": conv_init(next(keys), c_in, _MINI_PLAN[0][0])}
    params["stages"] = []
    c_prev = _MINI_PLAN[0][0]
    for width, n_blocks in _MINI_PLAN:
        stage = {"transition": conv_init(next(keys), c_prev, width)}
        stage["blocks"] = [_block_init(next(keys), width) for _ in range(n_blocks)]
        params["stages"].append(stage)
        c_prev = width
    params["head"] = head_init(next(keys), c_prev, n_classes)
    return params


def apply_mini(params, x):
    """Logits for ``x: [B, 16, 16, 3]``."""
    h = relu(conv(params["stem"], x))
    for i, (width, _) in enumerate(_MINI_PLAN):
        stage = params["stages"][i]
        stride = 1 if i == 0 else 2
        h = relu(conv(stage["transition"], h, stride=stride))
        for block in stage["blocks"]:
            h = _block_apply(block, h)
    return dense(params["head"], global_avg_pool(h))


def loss_mini(params, x, y):
    return cross_entropy(apply_mini(params, x), y)
