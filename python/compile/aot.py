"""AOT compile path: lower L2/L1 to HLO text artifacts for the Rust runtime.

Run once via ``make artifacts``. For every selected model this writes:

  * ``<model>.grad.hlo.txt``   — grad_moments step (see model.py)
  * ``<model>.fwd.hlo.txt``    — batched logits (classifiers) or
    ``<model>.evloss.hlo.txt`` — mean eval loss (LMs)
  * ``<model>.params.bin``     — initial flat parameters, little-endian f32
  * plus shared micro-bench artifacts (standalone moments kernel) and the
    XLA-offload criterion, and a ``manifest.json`` describing everything.

Interchange format is HLO **text**, never ``.serialize()``: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py and its README.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

DEFAULT_MODELS = ["mlp", "vgg_tiny", "resnet_mini", "transformer"]
FULL_MODELS = DEFAULT_MODELS + ["vgg_cifar"]

# Standalone kernel micro-bench shapes (B, N).
MOMENTS_BENCH_SHAPES = [(64, 65536)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the Rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def _dtype_name(dt):
    return np.dtype(dt).name


def lower_model(spec, out_dir, workers, batch, chunk, eval_batch, seed):
    """Lower one model's grad + eval artifacts; return its manifest entry."""
    print(f"[{spec.name}] init (seed={seed})")
    flat0, unravel, groups = M.init_flat(spec, seed=seed)
    n = int(flat0.shape[0])
    print(f"[{spec.name}] N={n} params, P={workers}, B={batch}, C={chunk}")

    params_path = os.path.join(out_dir, f"{spec.name}.params.bin")
    np.asarray(flat0, dtype="<f4").tofile(params_path)

    sample_shape = tuple(spec.sample_shape)
    p_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    xs_spec = jax.ShapeDtypeStruct((workers, batch) + sample_shape, spec.sample_dtype)
    ys_spec = jax.ShapeDtypeStruct((workers, batch), spec.label_dtype)

    step = M.make_grad_moments(spec, unravel, workers, batch, chunk)
    grad_file = f"{spec.name}.grad.hlo.txt"
    print(f"[{spec.name}] lowering grad_moments ...")
    _write(
        os.path.join(out_dir, grad_file),
        to_hlo_text(jax.jit(step, keep_unused=True).lower(p_spec, xs_spec, ys_spec)),
    )

    xe_spec = jax.ShapeDtypeStruct((eval_batch,) + sample_shape, spec.sample_dtype)
    if spec.kind == "classifier":
        fwd = M.make_forward(spec, unravel)
        eval_file = f"{spec.name}.fwd.hlo.txt"
        eval_kind = "logits"
    else:
        fwd = M.make_eval_loss(spec, unravel)
        eval_file = f"{spec.name}.evloss.hlo.txt"
        eval_kind = "loss"
    print(f"[{spec.name}] lowering eval ({eval_kind}) ...")
    _write(
        os.path.join(out_dir, eval_file),
        to_hlo_text(jax.jit(fwd, keep_unused=True).lower(p_spec, xe_spec)),
    )

    return {
        "name": spec.name,
        "kind": spec.kind,
        "n_params": n,
        "workers": workers,
        "batch": batch,
        "chunk": chunk,
        "eval_batch": eval_batch,
        "n_classes": spec.n_classes,
        "sample_shape": list(sample_shape),
        "sample_dtype": _dtype_name(spec.sample_dtype),
        "label_dtype": _dtype_name(spec.label_dtype),
        "grad_hlo": grad_file,
        "eval_hlo": eval_file,
        "eval_kind": eval_kind,
        "params_bin": f"{spec.name}.params.bin",
        "groups": groups,
        "seed": seed,
    }


def lower_shared(out_dir, criterion_sizes):
    """Kernel micro-bench + criterion-offload artifacts."""
    shared = {"moments_bench": [], "criterion": []}
    mom = M.make_moments_bench()
    for b, n in MOMENTS_BENCH_SHAPES:
        fname = f"moments_b{b}_n{n}.hlo.txt"
        print(f"[shared] lowering moments bench b={b} n={n} ...")
        g_spec = jax.ShapeDtypeStruct((b, n), jnp.float32)
        _write(os.path.join(out_dir, fname), to_hlo_text(jax.jit(mom).lower(g_spec)))
        shared["moments_bench"].append({"b": b, "n": n, "hlo": fname})

    crit = M.make_criterion()
    for n in criterion_sizes:
        fname = f"criterion_n{n}.hlo.txt"
        print(f"[shared] lowering criterion n={n} ...")
        v_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
        a_spec = jax.ShapeDtypeStruct((), jnp.float32)
        _write(
            os.path.join(out_dir, fname),
            to_hlo_text(jax.jit(crit).lower(v_spec, v_spec, a_spec)),
        )
        shared["criterion"].append({"n": n, "hlo": fname})
    return shared


def input_fingerprint():
    """Hash of the compile-path sources, recorded for staleness checks."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=None, help="comma-separated subset")
    ap.add_argument("--full", action="store_true", help="include vgg_cifar")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    names = (
        args.models.split(",")
        if args.models
        else (FULL_MODELS if args.full else DEFAULT_MODELS)
    )
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for name in names:
        spec = M.REGISTRY[name]
        entries.append(
            lower_model(
                spec,
                args.out_dir,
                workers=spec.default_workers,
                batch=spec.default_batch,
                chunk=spec.default_chunk,
                eval_batch=spec.default_eval_batch,
                seed=args.seed,
            )
        )

    crit_sizes = sorted({e["n_params"] for e in entries if e["name"] == "vgg_tiny"})
    if not crit_sizes:
        crit_sizes = [entries[0]["n_params"]]
    shared = lower_shared(args.out_dir, crit_sizes)

    manifest = {
        "format_version": 1,
        "fingerprint": input_fingerprint(),
        "models": entries,
        "shared": shared,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    sys.exit(main())
