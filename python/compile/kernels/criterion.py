"""Element-wise variance-criterion kernel (Eq. 3 of the paper).

Decides, per parameter, whether the accumulated gradient is unambiguous
enough to send: ``send_i ⇔ r_i² > α v_i``. Appendix A shows this efficient
form is algebraically equivalent to the variance criterion (Eq. 1), so the
kernel needs only the two running sums maintained by `moments.py` — no
explicit variance is ever materialised.

The coordinator evaluates this criterion natively in Rust on the hot path
(the r/v state lives in L3); this kernel exists as the XLA-offload variant
(`repro train --xla-criterion`) and as the ablation point for the
native-vs-XLA decision bench. Same TPU mapping rationale as `moments.py`:
1-D grid over N tiles, pure VPU work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_N = 512


def _criterion_kernel(alpha_ref, r_ref, v_ref, mask_ref):
    r = r_ref[...]
    v = v_ref[...]
    alpha = alpha_ref[0]
    mask_ref[...] = (r * r > alpha * v).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_n",))
def criterion(r, v, alpha, tile_n=None):
    """Send mask for the accumulated state: 1.0 where ``r² > α v``.

    Args:
      r: f32 ``[N]`` accumulated mean-gradient vector.
      v: f32 ``[N]`` accumulated squared-mean vector.
      alpha: scalar (python float or 0-d array) unambiguity requirement.
      tile_n: block width; ``None`` = single block (see
        ``moments.moments`` for the interpret-mode rationale; 512 is the
        real-TPU BlockSpec).

    Returns:
      f32 ``[N]`` mask.
    """
    (n,) = r.shape
    tile_n = min(tile_n if tile_n is not None else n, max(n, 1))
    n_pad = (-n) % tile_n
    if n_pad:
        # Pad v with 1s and r with 0s: 0² > α·1 is false, pad never sends.
        r = jnp.pad(r, (0, n_pad))
        v = jnp.pad(v, (0, n_pad), constant_values=1.0)
    n_full = n + n_pad
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape((1,))

    mask = pl.pallas_call(
        _criterion_kernel,
        grid=(n_full // tile_n,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_full,), jnp.float32),
        interpret=True,
    )(alpha_arr, r.astype(jnp.float32), v.astype(jnp.float32))
    return mask[:n]
