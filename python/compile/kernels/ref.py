"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth used by pytest/hypothesis to validate the
Pallas implementations in `moments.py` and `criterion.py`. They are also
what the kernels must lower to semantically: one pass over the per-sample
gradient block producing the two running sums the paper's Algorithm 1
maintains (r_i += sum_z grad_i f_z / B, v_i += sum_z (grad_i f_z / B)^2).
"""

import jax.numpy as jnp


def moments_ref(g):
    """Raw first and second moment sums over the sample axis.

    Args:
      g: ``[B, N]`` per-sample gradient block.

    Returns:
      ``(sum, sumsq)`` where ``sum[i] = Σ_z g[z, i]`` and
      ``sumsq[i] = Σ_z g[z, i]^2``, both ``[N]`` and in f32.
    """
    g = g.astype(jnp.float32)
    return g.sum(axis=0), (g * g).sum(axis=0)


def criterion_ref(r, v, alpha):
    """The paper's efficient send criterion (Eq. 3): ``r_i^2 > α v_i``.

    Args:
      r: ``[N]`` accumulated mean-gradient (delayed update) vector.
      v: ``[N]`` accumulated squared-mean vector.
      alpha: scalar unambiguity requirement (1..2 per the paper).

    Returns:
      ``[N]`` float32 mask, 1.0 where the element should be sent.
    """
    r = r.astype(jnp.float32)
    v = v.astype(jnp.float32)
    return (r * r > alpha * v).astype(jnp.float32)
