"""Fused per-sample gradient moment kernel (the paper's hot-spot).

Algorithm 1 of the paper maintains, per parameter i, two running sums over
per-sample gradients: ``r_i += Σ_z ∇_i f_z / B`` and
``v_i += Σ_z (∇_i f_z / B)^2``. The additional compute the method costs is
exactly these ``2 N |B|`` multiply-adds (Sec. 5). This kernel performs the
inner reduction — raw ``Σ_z g`` and ``Σ_z g²`` over a ``[B, N]`` block of
per-sample gradients — in a single fused pass.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is 1-D over N tiles;
each grid step streams one ``[B, TILE_N]`` block HBM→VMEM once and reduces
both moments in VMEM over the sublane (batch) axis, keeping the VPU lanes
full and the MXU idle (element-wise work must not occupy the MXU). With
B=64 and TILE_N=512 the block is 128 KiB — far below VMEM; the kernel is
memory-bound at 2 FLOPs per 4-byte load, i.e. it runs at the HBM roofline.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and these artifacts run on the Rust CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane-aligned TPU tile. 512 f32 = 4 sublane registers of 128 lanes.
# This is the production BlockSpec for real-TPU lowering (DESIGN.md
# §Perf: B=64 × 512 × 4B = 128 KiB per block, far under VMEM).
DEFAULT_TILE_N = 512


def _moments_kernel(g_ref, sum_ref, sumsq_ref):
    """One grid step: reduce a [B, TILE_N] block over the batch axis."""
    g = g_ref[...].astype(jnp.float32)
    sum_ref[...] = jnp.sum(g, axis=0)
    sumsq_ref[...] = jnp.sum(g * g, axis=0)


@functools.partial(jax.jit, static_argnames=("tile_n",))
def moments(g, tile_n=None):
    """Fused ``(Σ_z g, Σ_z g²)`` over the sample axis of ``g: [B, N]``.

    N is padded to a multiple of ``tile_n`` with zeros (zeros contribute
    nothing to either sum) and the pad is stripped from the outputs.

    ``tile_n=None`` (default) uses a single block covering all of N.
    Rationale (EXPERIMENTS.md §Perf L1): in ``interpret=True`` mode each
    grid step is *emulated* at HLO level; on this single-core CPU
    testbed a 143-step grid costs ~16× the whole remaining step. VMEM
    does not constrain the interpret path, so the AOT artifacts use one
    block; on a real TPU the same kernel lowers with
    ``tile_n=DEFAULT_TILE_N`` to respect VMEM (the tiled path stays
    covered by the hypothesis suite).

    Returns:
      ``(sum, sumsq)``, both f32 ``[N]``.
    """
    b, n = g.shape
    tile_n = min(tile_n if tile_n is not None else n, max(n, 1))
    n_pad = (-n) % tile_n
    if n_pad:
        g = jnp.pad(g, ((0, 0), (0, n_pad)))
    n_full = n + n_pad
    grid = (n_full // tile_n,)

    out_shape = (
        jax.ShapeDtypeStruct((n_full,), jnp.float32),
        jax.ShapeDtypeStruct((n_full,), jnp.float32),
    )
    s, ss = pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, tile_n), lambda i: (0, i))],
        out_specs=(
            pl.BlockSpec((tile_n,), lambda i: (i,)),
            pl.BlockSpec((tile_n,), lambda i: (i,)),
        ),
        out_shape=out_shape,
        interpret=True,
    )(g)
    return s[:n], ss[:n]


def scaled_moments(g, batch_size):
    """Algorithm-1 scaled moments of a per-sample gradient block.

    Returns ``(Σ_z g / B, Σ_z (g / B)²) = (sum / B, sumsq / B²)`` — the
    exact per-step increments of the paper's ``r`` and ``v`` accumulators
    when ``B = batch_size`` (the block may be a microbatch chunk of B).
    """
    s, ss = moments(g)
    inv_b = 1.0 / float(batch_size)
    return s * inv_b, ss * (inv_b * inv_b)
